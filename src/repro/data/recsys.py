"""Synthetic sequential-recommendation data (SASRec shapes)."""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class RecStreamConfig:
    n_items: int
    seq_len: int
    batch: int
    seed: int = 0


def batch_at_step(cfg: RecStreamConfig, step: int):
    """Returns (item_seq, pos_items, neg_items), each (B, S) int32.
    Item 0 is padding. Sequences follow seeded item-cluster dynamics so the
    BPR loss is learnable."""
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
    B, S, V = cfg.batch, cfg.seq_len, cfg.n_items
    cluster = rng.integers(1, max(V // 64, 2), (B, 1))
    walk = (cluster * 64 + rng.integers(0, 64, (B, S + 1))) % (V - 1) + 1
    seq = walk[:, :-1].astype(np.int32)
    pos = walk[:, 1:].astype(np.int32)
    neg = rng.integers(1, V, (B, S)).astype(np.int32)
    # pad a random prefix (variable-length histories)
    plen = rng.integers(0, S // 2, (B, 1))
    mask = np.arange(S)[None, :] < plen
    seq = np.where(mask, 0, seq)
    pos = np.where(mask, 0, pos)
    return seq, pos, neg
