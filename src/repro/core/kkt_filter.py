"""Karger–Klein–Tarjan sampling filter (paper Section 3.1, Algorithms 3+5).

Reduces MSF query complexity from O(m log n) to O(m + n log^2 n):

  1. sample each edge with p = 1/log n, compute F = MSF(sample);
  2. classify every edge of G as F-light / F-heavy (Definition 3.7) —
     F-heavy edges cannot be in the MSF (Proposition 3.8) and are dropped;
  3. MSF(F ∪ F-light edges) is the answer (expected |F-light| = O(n log n)).

The F-light test needs, per edge (u,v): "are u,v in the same tree of F, and
if so what is the maximum edge weight on the F-path u→v?".  Following
Appendix B we build the machinery with basic parallel tree algorithmics, all
inside O(1) launches:

  * Euler tour of the (unrooted) forest via twin-arc successor construction;
  * list ranking by pointer doubling (in-round);
  * parent / root extraction from first-entry arcs;
  * vertex levels by parent-pointer doubling;
  * LCA + path-max by binary lifting (the paper uses Euler-RMQ + heavy-light
    decomposition; binary lifting gives the same O(n log n) space and O(1)
    rounds with a better SIMD fit — substitution documented in DESIGN.md).

A sparse-table RMQ (the paper's B.3 structure) is provided as a utility and
property-tested; it is used by benchmarks to reproduce the Appendix-B path.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.coo import UGraph
from .rounds import RoundLedger

INT32_MAX = jnp.iinfo(jnp.int32).max


# --------------------------------------------------------------------------
# Sparse-table RMQ (Appendix B utility)
# --------------------------------------------------------------------------
def rmq_build(a: jnp.ndarray) -> jnp.ndarray:
    """b[x, y] = min(a[x : x + 2^y]) — O(k log k), built in log k steps."""
    k = a.shape[0]
    levels = max(int(np.ceil(np.log2(max(k, 2)))) + 1, 1)
    rows = [a]
    for y in range(1, levels):
        half = 1 << (y - 1)
        prev = rows[-1]
        shifted = jnp.concatenate([prev[half:], jnp.full((half,), prev.dtype.type(
            np.inf if jnp.issubdtype(prev.dtype, jnp.floating) else INT32_MAX))])
        rows.append(jnp.minimum(prev, shifted))
    return jnp.stack(rows)  # (levels, k)


def rmq_query(table: jnp.ndarray, i: jnp.ndarray, j: jnp.ndarray) -> jnp.ndarray:
    """min(a[i..j]) inclusive, vectorized over query arrays."""
    length = j - i + 1
    t = jnp.where(length > 0, jnp.int32(jnp.floor(jnp.log2(
        jnp.maximum(length, 1).astype(jnp.float32)))), 0)
    left = table[t, i]
    right = table[t, jnp.maximum(j - (1 << t) + 1, i)]
    return jnp.minimum(left, right)


# --------------------------------------------------------------------------
# Euler tour + list ranking + rooting of an unrooted forest
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("n",))
def root_forest(fu, fv, fw, fvalid, n: int):
    """Orient a forest: returns (parent(n,), parent_w(n,), depth(n,)).

    fu/fv/fw: (K,) forest edges with validity mask.  Roots have parent=self,
    parent_w=+inf, depth=0.  Runs in one launch: Euler tour construction,
    list ranking by doubling, first-entry parent extraction, depth doubling.
    """
    K = fu.shape[0]
    A = 2 * K  # arcs: 2e = (u->v), 2e+1 = (v->u); twin(a) = a ^ 1
    src = jnp.stack([fu, fv], axis=1).reshape(-1)
    dst = jnp.stack([fv, fu], axis=1).reshape(-1)
    w2 = jnp.stack([fw, fw], axis=1).reshape(-1)
    avalid = jnp.stack([fvalid, fvalid], axis=1).reshape(-1)
    aid = jnp.arange(A, dtype=jnp.int32)

    # sort arcs by (src), invalid last
    skey = jnp.where(avalid, src, n)
    order = jnp.argsort(skey * jnp.int32(A) + aid)   # stable by construction
    inv_order = jnp.zeros((A,), jnp.int32).at[order].set(aid)
    sorted_src = skey[order]
    start = jnp.searchsorted(sorted_src, jnp.arange(n + 1, dtype=jnp.int32)
                             ).astype(jnp.int32)
    deg = start[1:] - start[:-1]                     # (n,) arc out-degree

    # succ(a) = cyclic-next arc (by src) after twin(a)
    twin = aid ^ 1
    t_pos = inv_order[twin]                          # position of twin in sort
    t_src = jnp.where(avalid, dst, 0)                # twin's src == my dst
    base = start[t_src]
    nxt_pos = base + (t_pos - base + 1) % jnp.maximum(deg[t_src], 1)
    succ = jnp.where(avalid, order[nxt_pos], aid)

    # per-tree root arc: min arc id among arcs whose src is in the tree; we
    # identify trees by min-vertex label via doubling on succ (arc cycles)
    min_arc = aid
    def dbl(i, s):
        ma, sc = s
        ma = jnp.minimum(ma, ma[sc])
        return ma, sc[sc]
    iters = int(np.ceil(np.log2(max(A, 2)))) + 1
    min_arc, _ = jax.lax.fori_loop(0, iters, dbl, (min_arc, succ))
    is_root_arc = avalid & (min_arc == aid)

    # break the Euler cycles before the root arcs: prev(root) -> self
    last = succ == aid
    prev_of = jnp.zeros((A,), jnp.int32).at[succ].set(aid)  # unique where cycle
    succ = jnp.where(is_root_arc[succ] & ~last, aid, succ)

    # list ranking: d[a] = number of arcs strictly after a in its tour
    d = jnp.where(succ != aid, 1, 0).astype(jnp.int32)
    def rank_dbl(i, s):
        d, p = s
        d = d + d[p]
        return d, p[p]
    d, _ = jax.lax.fori_loop(0, iters, rank_dbl, (d, succ))
    # position within tree: pos[a] = d[root_arc(tree)] - d[a]; root pos = 0
    root_arc_of = jnp.where(is_root_arc, aid, 0)
    # propagate each tree's root arc id via min_arc (min_arc == root arc id)
    pos = d[min_arc] - d

    # parent: first arc entering v (min pos among arcs with dst == v); the
    # tour root of each tree (src of its root arc) keeps parent = self even
    # though later arcs re-enter it
    ids = jnp.arange(n, dtype=jnp.int32)
    is_tour_root = jnp.zeros((n,), bool).at[
        jnp.where(is_root_arc, src, n)].set(True, mode="drop")
    posbig = jnp.where(avalid, pos, INT32_MAX)
    dsafe = jnp.where(avalid, dst, n)
    min_pos = jax.ops.segment_min(posbig, dsafe, num_segments=n + 1)[:n]
    lane = jnp.where(avalid & (pos <= min_pos[dsafe]), aid, INT32_MAX)
    min_lane = jax.ops.segment_min(lane, dsafe, num_segments=n + 1)[:n]
    has_parent = (min_lane < INT32_MAX) & ~is_tour_root
    ml = jnp.clip(min_lane, 0, A - 1)
    parent = jnp.where(has_parent, src[ml], ids)
    parent_w = jnp.where(has_parent, w2[ml], jnp.float32(jnp.inf))

    # depth by parent doubling
    depth = jnp.where(parent != ids, 1, 0).astype(jnp.int32)
    def depth_dbl(i, s):
        dep, p = s
        dep = dep + dep[p]
        return dep, p[p]
    itn = int(np.ceil(np.log2(max(n, 2)))) + 1
    depth, _ = jax.lax.fori_loop(0, itn, depth_dbl, (depth, parent))
    return parent, parent_w, depth


def _lift_tables(parent, parent_w, levels: int):
    """Binary lifting: anc[k][v] = 2^k-th ancestor, mx[k][v] = max edge weight
    on that jump (inf past the root)."""
    n = parent.shape[0]
    ids = jnp.arange(n, dtype=jnp.int32)
    anc = [parent]
    mx = [jnp.where(parent != ids, parent_w, jnp.float32(-jnp.inf))]
    for k in range(1, levels):
        a_prev, m_prev = anc[-1], mx[-1]
        anc.append(a_prev[a_prev])
        mx.append(jnp.maximum(m_prev, m_prev[a_prev]))
    return jnp.stack(anc), jnp.stack(mx)  # (levels, n)


@functools.partial(jax.jit, static_argnames=("levels",))
def path_max_queries(parent, parent_w, depth, comp, qu, qv, levels: int):
    """For each query pair (qu[i], qv[i]) in the same tree: max edge weight on
    the tree path (via LCA by binary lifting).  Different trees -> +inf.
    Returns (maxw, same_tree)."""
    anc, mx = _lift_tables(parent, parent_w, levels)

    def one(u, v):
        same = comp[u] == comp[v]
        du, dv = depth[u], depth[v]
        # lift the deeper one
        def lift(node, dd):
            def step(k, s):
                node, dd = s
                take = (dd >> k) & 1
                m_add = jnp.where(take == 1, mx[k, node], jnp.float32(-jnp.inf))
                node = jnp.where(take == 1, anc[k, node], node)
                return node, dd
            best = jnp.float32(-jnp.inf)
            # accumulate max while lifting
            def step2(k, s):
                node, best = s
                take = (dd >> k) & 1
                best = jnp.where(take == 1, jnp.maximum(best, mx[k, node]), best)
                node = jnp.where(take == 1, anc[k, node], node)
                return node, best
            node, best = jax.lax.fori_loop(0, levels, step2, (node, jnp.float32(-jnp.inf)))
            return node, best

        swap = du < dv
        a = jnp.where(swap, v, u)
        b = jnp.where(swap, u, v)
        diff = jnp.abs(du - dv)

        def lift_by(node, diff):
            def step(k, s):
                node, best = s
                take = (diff >> k) & 1
                best = jnp.where(take == 1, jnp.maximum(best, mx[k, node]), best)
                node = jnp.where(take == 1, anc[k, node], node)
                return node, best
            return jax.lax.fori_loop(0, levels, step, (node, jnp.float32(-jnp.inf)))

        a2, best = lift_by(a, diff)

        def together(k, s):
            na, nb, best = s
            kk = levels - 1 - k
            differ = anc[kk, na] != anc[kk, nb]
            best = jnp.where(differ, jnp.maximum(best,
                             jnp.maximum(mx[kk, na], mx[kk, nb])), best)
            na = jnp.where(differ, anc[kk, na], na)
            nb = jnp.where(differ, anc[kk, nb], nb)
            return na, nb, best

        eq = a2 == b
        na, nb, best2 = jax.lax.fori_loop(0, levels, together, (a2, b, best))
        final = jnp.where(eq, best, jnp.maximum(best2,
                          jnp.maximum(mx[0, na], mx[0, nb])))
        return jnp.where(same, final, jnp.float32(jnp.inf)), same

    return jax.vmap(one)(qu, qv)


# --------------------------------------------------------------------------
# F-light classification + the KKT MSF driver
# --------------------------------------------------------------------------
def f_light_edges(g: UGraph, forest_mask: np.ndarray,
                  ledger: Optional[RoundLedger] = None) -> np.ndarray:
    """Boolean (m,) — True iff the edge is F-light w.r.t. the forest."""
    from .msf import boruvka_inround  # component labels of F
    ledger = ledger if ledger is not None else RoundLedger("f_light")
    n, m = g.n, g.m
    K = int(forest_mask.sum())
    fe = g.edges[forest_mask]
    fw_np = g.weights[forest_mask]
    fu = jnp.asarray(fe[:, 0]) if K else jnp.zeros((1,), jnp.int32)
    fv = jnp.asarray(fe[:, 1]) if K else jnp.zeros((1,), jnp.int32)
    fw = jnp.asarray(fw_np) if K else jnp.zeros((1,), jnp.float32)
    fvalid = jnp.ones((max(K, 1),), bool) if K else jnp.zeros((1,), bool)

    with ledger.shuffle("forest_components", K * 8):
        _, comp, _ = boruvka_inround(fu, fv, fw,
                                     jnp.arange(max(K, 1), dtype=jnp.int32),
                                     fvalid, n, max(K, 1))
    with ledger.shuffle("euler_root", K * 8):
        parent, parent_w, depth = root_forest(fu, fv, fw, fvalid, n)
    levels = max(int(np.ceil(np.log2(max(n, 2)))) + 1, 1)
    with ledger.shuffle("path_max", m * 8):
        qu = jnp.asarray(g.edges[:, 0]); qv = jnp.asarray(g.edges[:, 1])
        maxw, same = path_max_queries(parent, parent_w, depth, comp,
                                      qu, qv, levels)
        maxw = np.asarray(jax.device_get(maxw))
        same = np.asarray(jax.device_get(same))
    ledger.record_queries(2 * m * levels, 2 * m * levels * 8, waves=1)
    # Definition 3.7: different components -> light; else light iff w <= maxpath
    light = (~same) | (g.weights <= maxw)
    return light


def msf_kkt(g: UGraph, epsilon: float = 0.5, seed: int = 0,
            ledger: Optional[RoundLedger] = None) -> Tuple[np.ndarray, dict]:
    """Algorithm 3: sample -> MSF(sample) -> F-light filter -> MSF(F ∪ light).
    Returns (mask over g.edges, stats)."""
    from ..ampc.solvers import msf_ampc
    ledger = ledger if ledger is not None else RoundLedger("ampc_msf_kkt")
    n, m = g.n, g.m
    rng = np.random.default_rng(seed)
    p = 1.0 / max(np.log(max(n, 3)), 2.0)
    with ledger.shuffle("sample", m):
        smask = rng.random(m) < p
        if not smask.any():
            smask[rng.integers(m)] = True
        h = UGraph(n, g.edges[smask], g.weights[smask])
    fmask_h, st1 = msf_ampc(h, epsilon=epsilon, seed=seed, ledger=ledger)
    fmask = np.zeros(m, bool)
    fmask[np.where(smask)[0][fmask_h]] = True

    light = f_light_edges(g, fmask, ledger=ledger)
    keep = light | fmask
    g2 = UGraph(n, g.edges[keep], g.weights[keep])
    mask2, st2 = msf_ampc(g2, epsilon=epsilon, seed=seed + 1, ledger=ledger)
    mask = np.zeros(m, bool)
    mask[np.where(keep)[0][mask2]] = True
    stats = {"sample_p": p, "sample_edges": int(smask.sum()),
             "forest_edges": int(fmask.sum()),
             "light_edges": int(light.sum()),
             "filtered_away": int(m - keep.sum()),
             "inner": [st1, st2]}
    return mask, stats
