"""GSPMD sharding rules for every architecture family.

Strategy (baseline, see EXPERIMENTS.md §Perf for the hill-climbed variants):

LM params (Megatron-TP x ZeRO-FSDP):
  * attention/MLP in-projections  (d, out):  P("data", "model")
  * attention/MLP out-projections (in, d):   P("model", "data")
  * MoE experts (E, d, f):                   P(None, "data", "model")
  * embedding (V, d):                        P("model", "data")   [vocab-TP]
  * lm_head (d, V):                          P("data", "model")
  * norms / biases / scalars:                replicated
  optimizer state inherits the param rule (ZeRO: state lives sharded).

LM batch: tokens (B, S) -> P(dp, None) with dp = ("pod","data")|("data",).
KV cache: B >= |dp| -> batch-sharded; B == 1 (long_500k) -> sequence-sharded
cache + head_dim over "model" (all head_dims divide 16).

GNN: node/edge arrays sharded over ALL axes flattened (pure data parallel on
segments); params replicated (they are tiny relative to the graph).

RecSys: embedding rows over "model" (the sharded DHT), batch over dp.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .mesh import data_axes


# --------------------------------------------------------------------------
# LM parameter rules (path-pattern -> spec builder)
# --------------------------------------------------------------------------
def lm_param_spec(path: str, ndim: int, dp) -> P:
    """path: '/'-joined key path of the param leaf (layer-stacked params have
    a leading L dim — rules below index from the right)."""
    def stacked(*spec):
        # layer-stacked leaves have one extra leading dim (replicated)
        pad = ndim - len(spec)
        return P(*([None] * pad), *spec)

    if re.search(r"embed$", path):
        return P("model", dp)
    if re.search(r"lm_head$", path):
        return P(dp, "model")
    if re.search(r"attn/(wq|wk|wv)$", path):
        return stacked(dp, "model")
    if re.search(r"attn/wo$", path):
        return stacked("model", dp)
    if re.search(r"mlp/(w_gate|w_up)$", path):
        return stacked(dp, "model")
    if re.search(r"mlp/w_down$", path):
        return stacked("model", dp)
    if re.search(r"moe/(w_gate|w_up)$", path):
        return stacked(None, dp, "model")
    if re.search(r"moe/w_down$", path):
        return stacked(None, "model", dp)
    if re.search(r"moe/shared/(w_gate|w_up)$", path):
        return stacked(dp, "model")
    if re.search(r"moe/shared/w_down$", path):
        return stacked("model", dp)
    if re.search(r"moe/router$", path):
        return stacked(dp, None)
    return P()  # norms, biases, scalars: replicated


def _tree_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        keys = []
        for p in path:
            if hasattr(p, "key"):
                keys.append(str(p.key))
            elif hasattr(p, "idx"):
                keys.append(str(p.idx))
            else:
                keys.append(str(p))
        out.append(("/".join(keys), leaf))
    return out, treedef


def lm_param_shardings(mesh, params_shape) -> Any:
    """Map a params (or optimizer-state) shape pytree to NamedShardings."""
    dp = data_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]
    flat, treedef = _tree_paths(params_shape)
    shardings = []
    for path, leaf in flat:
        if not hasattr(leaf, "ndim") or leaf.ndim == 0:
            shardings.append(NamedSharding(mesh, P()))
            continue
        spec = lm_param_spec(path, leaf.ndim, dp)
        # drop axes that do not divide evenly (fallback to replicated там)
        spec = _fix_divisibility(spec, leaf.shape, mesh)
        shardings.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, shardings)


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _fix_divisibility(spec: P, shape, mesh) -> P:
    fixed = []
    for i, axis in enumerate(spec):
        if axis is None or i >= len(shape):
            fixed.append(None)
            continue
        if shape[i] % _axis_size(mesh, axis) == 0:
            fixed.append(axis)
        else:
            fixed.append(None)
    return P(*fixed)


def replicated(mesh, tree):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def batch_sharding(mesh, ndim: int, batch_axis: int = 0):
    dp = data_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]
    spec = [None] * ndim
    spec[batch_axis] = dp
    return NamedSharding(mesh, P(*spec))


def kv_cache_shardings(mesh, cache_shape, global_batch: int):
    """cache k/v: (L, B, S, Hkv, hd)."""
    dp = data_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    dpx = dp if len(dp) > 1 else dp[0]
    L, B, S, Hkv, hd = cache_shape
    if global_batch >= dp_size and global_batch % dp_size == 0:
        spec = P(None, dpx, None, None,
                 "model" if hd % mesh.shape["model"] == 0 else None)
    else:
        # long-context single stream: sequence-parallel cache
        seq_ax = "data" if S % mesh.shape["data"] == 0 else None
        spec = P(None, None, seq_ax, None,
                 "model" if hd % mesh.shape["model"] == 0 else None)
    return NamedSharding(mesh, spec)


def flat_shard(mesh, ndim: int, axis: int = 0):
    """Shard dim `axis` over ALL mesh axes (GNN node/edge arrays)."""
    all_axes = tuple(mesh.axis_names)
    spec = [None] * ndim
    spec[axis] = all_axes
    return NamedSharding(mesh, P(*spec))


def rec_param_shardings(mesh, params_shape):
    dp = data_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]
    flat, treedef = _tree_paths(params_shape)
    out = []
    for path, leaf in flat:
        if path.endswith("item_embed") and leaf.shape[0] % mesh.shape["model"] == 0:
            out.append(NamedSharding(mesh, P("model", None)))
        else:
            out.append(NamedSharding(mesh, P()))
    return jax.tree_util.tree_unflatten(treedef, out)
