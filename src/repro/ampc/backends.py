"""Pluggable DHT backends for the AMPC engine.

The paper's AMPC model has exactly one shared primitive: an immutable
distributed hash table written by the previous round and queried adaptively
inside the current one.  ``core.dht`` provides two execution schedules for
that primitive — a plain device gather (``lookup``) and an explicit
``shard_map`` all_to_all router (``routed_lookup``).  This module promotes
both behind one ``DhtBackend`` protocol so the engine (and any solver) can
issue lookups without knowing which schedule runs underneath, and so ledger
accounting (queries, bytes, dedup savings, waves, overflows) is identical on
both paths.

Backends are stateless between solves: ``snapshot(values)`` binds a value
array + ledger into a ``core.dht.ShardedDHT`` and every query goes through
``ShardedDHT.lookup`` — the single accounting choke point.
"""
from __future__ import annotations

from typing import Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from ..core.dht import ShardedDHT


@runtime_checkable
class DhtBackend(Protocol):
    """One immutable-snapshot KV store; the only AMPC communication primitive."""

    name: str

    def snapshot(self, values, ledger=None,
                 value_bytes: Optional[int] = None) -> ShardedDHT:
        """Write ``values`` (row i = value of key i) into the DHT."""
        ...

    def lookup(self, values, keys, *, ledger=None, dedup: bool = True,
               value_bytes: Optional[int] = None):
        """One-shot snapshot + query batch (convenience for single reads)."""
        ...


class _BackendBase:
    def lookup(self, values, keys, *, ledger=None, dedup: bool = True,
               value_bytes: Optional[int] = None):
        return self.snapshot(values, ledger=ledger,
                             value_bytes=value_bytes).lookup(keys, dedup=dedup)


class LocalDht(_BackendBase):
    """Gather-based DHT: ``jnp.take`` which XLA partitions under pjit."""

    name = "local"

    def snapshot(self, values, ledger=None,
                 value_bytes: Optional[int] = None) -> ShardedDHT:
        return ShardedDHT(jnp.asarray(values), ledger=ledger,
                          value_bytes=value_bytes)

    def __repr__(self):
        return "LocalDht()"


class RoutedDht(_BackendBase):
    """Explicit router DHT: dedup -> bucket by owner -> all_to_all -> answer.

    This is the collective schedule an RDMA KV store replaces (paper
    Section 5).  ``mesh`` defaults to a 1-D mesh over every visible device;
    pass a production mesh + ``axis_name`` to shard over one of its axes.
    """

    name = "routed"

    def __init__(self, mesh=None, axis_name: Optional[str] = None,
                 capacity: Optional[int] = None):
        if mesh is None:
            devices = jax.devices()
            mesh = jax.make_mesh((len(devices),), ("dht",))
            axis_name = "dht"
        self.mesh = mesh
        self.axis_name = axis_name or mesh.axis_names[0]
        self.capacity = capacity

    def snapshot(self, values, ledger=None,
                 value_bytes: Optional[int] = None) -> ShardedDHT:
        return ShardedDHT(jnp.asarray(values), ledger=ledger,
                          value_bytes=value_bytes, mesh=self.mesh,
                          axis_name=self.axis_name, capacity=self.capacity)

    def __repr__(self):
        return (f"RoutedDht(axis={self.axis_name!r}, "
                f"shards={self.mesh.shape[self.axis_name]})")


def resolve_backend(spec, mesh=None) -> DhtBackend:
    """Map ``"local" | "routed" | DhtBackend-instance`` to a backend object."""
    if isinstance(spec, str):
        if spec == "local":
            return LocalDht()
        if spec == "routed":
            return RoutedDht(mesh=mesh)
        raise ValueError(
            f"unknown dht_backend {spec!r}; expected 'local', 'routed', or a "
            "DhtBackend instance")
    if isinstance(spec, DhtBackend):
        return spec
    raise TypeError(f"dht_backend must be str or DhtBackend, got {type(spec)}")
