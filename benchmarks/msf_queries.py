"""Lemma 3.3/3.4 validation at scale: Prim query complexity O(n log n) and
vertex shrink factor n^{eps/2} across graph sizes; KKT filter effectiveness
(Lemma 3.9).  Solves dispatched through the AmpcEngine."""
from __future__ import annotations

import numpy as np

from repro.ampc import AmpcEngine
from repro.graph import generators as gen

from .common import fmt_table
from .registry import bench


@bench("msf_queries", quick_kwargs={"log2_sizes": (10, 12)},
       summary="Lemma 3.3/3.4: Prim queries + shrink factor; KKT filter")
def run(log2_sizes=(10, 12, 14)):
    eng = AmpcEngine(epsilon=0.5, seed=0)
    rows = []
    for lg in log2_sizes:
        g = gen.rmat(lg, 8.0, seed=lg).with_random_weights(lg)
        st = eng.solve(g, "msf", skip_ternarize_if_dense=False).stats
        n = st["n_tern"]
        bound = n * np.log2(n)
        rows.append([f"2^{lg}", g.n, g.m, st["queries"],
                     f"{st['queries']/bound:.2f}",
                     f"{st['shrink_factor']:.1f}",
                     f"{n ** 0.25:.1f}"])
    out = fmt_table(["size", "n", "m", "prim queries", "q/(n log n)",
                     "shrink", "n^(eps/2)"], rows)
    print(out)

    # KKT filter: fraction of edges surviving the F-light test
    g = gen.rmat(13, 12.0, seed=5).with_random_weights(7)
    st = eng.solve(g, "msf-kkt").stats
    frac = st["filtered_away"] / g.m
    print(f"\nKKT filter: {st['filtered_away']}/{g.m} edges filtered "
          f"({100*frac:.0f}%); light bound O(n/p)={st['light_edges']} vs "
          f"n·log n={int(g.n*np.log(g.n))}")
    return {"rows": rows, "kkt": st, "markdown": out}


if __name__ == "__main__":
    run()
