"""Figures 3 + 9 reproduction: bytes shuffled (MPC vs AMPC) and bytes of
KV-store (DHT) communication; linear trend of DHT bytes vs edges."""
from __future__ import annotations

from repro.core import matching as mm, mis, msf
from repro.core.rounds import RoundLedger

from .common import GRAPHS, fmt_table


def run(graph_names=None):
    names = graph_names or list(GRAPHS)
    rows = []
    trend = []
    for gname in names:
        g = GRAPHS[gname]()
        la, lm = RoundLedger("ampc_mis"), RoundLedger("mpc_mis")
        mis.mis_ampc(g, seed=0, ledger=la)
        mis.mis_mpc_rootset(g, seed=0, ledger=lm)
        rows.append([gname, g.n, g.m,
                     f"{la.bytes_shuffled/1e6:.1f}",
                     f"{la.dht_bytes/1e6:.1f}",
                     f"{lm.bytes_shuffled/1e6:.1f}",
                     f"{lm.bytes_shuffled/max(la.bytes_shuffled,1):.1f}x"])
        trend.append((g.m, la.dht_bytes))
    out = fmt_table(["graph", "n", "m", "AMPC shuffle MB", "AMPC DHT MB",
                     "MPC shuffle MB", "MPC/AMPC shuffled"], rows)
    print(out)
    # Fig 9: DHT bytes scale linearly with edges
    import numpy as np
    ms = np.array([t[0] for t in trend], float)
    bs = np.array([t[1] for t in trend], float)
    corr = float(np.corrcoef(np.log(ms), np.log(bs))[0, 1])
    print(f"\nlog-log correlation(DHT bytes, edges) = {corr:.3f} "
          f"(paper Fig 9: consistent linear trend)")
    return {"rows": rows, "loglog_corr": corr, "markdown": out}


if __name__ == "__main__":
    run()
