"""Deterministic synthetic LM token pipeline.

Produces sharded (batch, seq) int32 token batches with next-token labels.
The stream is a seeded markov-ish mixture so the loss is learnable (tests
assert loss decreases).  Host-side numpy; deterministic in (seed, step) so
any worker can regenerate any shard — the property that makes data restart
and straggler re-dispatch trivial (no data state in checkpoints beyond the
step counter).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenStreamConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


def batch_at_step(cfg: TokenStreamConfig, step: int):
    """Returns (tokens (B, S), labels (B, S)) — deterministic in (seed, step)."""
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
    B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab
    # structured stream: ascending runs + noise => learnable
    starts = rng.integers(0, V, (B, 1))
    ramps = (starts + np.arange(S + 1)) % V
    noise = rng.integers(0, V, (B, S + 1))
    take_noise = rng.random((B, S + 1)) < 0.1
    seq = np.where(take_noise, noise, ramps).astype(np.int32)
    return seq[:, :-1], seq[:, 1:]


def shard_of_batch(tokens, labels, shard: int, n_shards: int):
    """Static round-robin sharding of the global batch (straggler re-dispatch
    re-assigns shard indices, not data)."""
    return tokens[shard::n_shards], labels[shard::n_shards]
