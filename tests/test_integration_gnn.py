"""Integration: sampled-minibatch GNN training (the minibatch_lg regime) —
NeighborSampler -> padded blocks -> jitted train step -> loss decreases."""
import dataclasses
import functools

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.graph import generators as gen
from repro.data.graphs import NeighborSampler
from repro.models.gnn import gcn
from repro.optim import adamw
from repro.launch import steps


def test_sampled_training_loss_decreases():
    g = gen.rmat(10, 10.0, seed=0)
    rng = np.random.default_rng(0)
    d_feat, n_classes = 32, 5
    # learnable labels: class = argmax of a fixed random projection
    proj = rng.standard_normal((d_feat, n_classes)).astype(np.float32)
    feat = rng.standard_normal((g.n, d_feat)).astype(np.float32)
    labels = (feat @ proj).argmax(-1).astype(np.int32)

    cfg = gcn.GCNConfig(n_layers=2, d_feat=d_feat, d_hidden=32,
                        n_classes=n_classes)
    params = gcn.init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = adamw.AdamWConfig(lr=5e-3, warmup_steps=2, total_steps=50,
                                weight_decay=0.0)
    opt = adamw.init_state(params)

    sampler = NeighborSampler(g, fanout=(8, 4), seed=1)
    seeds_per_step = 64
    step_fn = jax.jit(functools.partial(steps.gnn_train_step, "gcn-cora",
                                        cfg, opt_cfg))
    losses = []
    for it in range(12):
        seeds = rng.integers(0, g.n, seeds_per_step)
        block = sampler.sample_block(seeds, feat, labels)
        params, opt, m = step_fn(params, opt, block)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-3:]) < np.mean(losses[:3]), losses
