"""Minimum spanning forest in constant adaptive rounds (paper Section 3).

Pieces:
  * ``truncated_prim``  — Algorithm 1: per-vertex rank-truncated Prim search,
    vmapped over all vertices (each vertex = one AMPC "machine task"); three
    stopping conditions (budget, exhaustion, lower-rank hook).
  * ``pointer_jump``    — Proposition 3.2 forest contraction (in-round
    doubling on the immutable hook snapshot).
  * ``contract_edges``  — relabel + self-loop removal + min-weight dedup.
  * ``boruvka_inround`` — DenseMSF stand-in: Borůvka hook-and-contract run
    entirely inside one launch (AMPC adaptivity), used for the dense phase.
  * ``msf_ampc``        — Algorithm 2 driver (5 materialized shuffles, matching
    the paper's Table 3 accounting: SortGraph, PrimSearch, PointerJump,
    Contract, DenseMSF).
  * ``msf_mpc_boruvka`` — the paper's MPC baseline (red/blue Borůvka,
    3 shuffles per phase, O(log n) phases).

All functions return a boolean mask over the *original* edge ids.

The ``msf_ampc`` / ``msf_mpc_boruvka`` drivers are deprecated shims over
``repro.ampc.solvers``; the jitted primitives live here.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.coo import UGraph
from .rounds import RoundLedger

INF = jnp.float32(jnp.inf)


# --------------------------------------------------------------------------
# Algorithm 1: truncated Prim
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("capacity",))
def truncated_prim_capped(nbr, nbw, nbe, rank, budget, capacity: int):
    """``truncated_prim`` with the buffer *capacity* decoupled from the
    stopping *budget*.

    The buffers (visited set, output slots, frontier) are sized by the static
    ``capacity`` while the stopping condition compares against the traced
    ``budget`` (an int32 scalar, ``budget <= capacity``).  With
    ``capacity == budget`` the trajectory is identical to ``truncated_prim``;
    with ``capacity > budget`` the extra slots stay at their -1/inf fill and
    never win the frontier argmin, so outputs are still bit-identical.  This
    is what lets a vmapped batch of graphs share one compiled program while
    each lane keeps its own n-dependent budget.
    """
    n, D = nbr.shape
    F = D * capacity  # frontier capacity
    budget = jnp.asarray(budget, jnp.int32)

    def per_vertex(v):
        visited = jnp.full((capacity,), -1, jnp.int32).at[0].set(v)
        fdst = jnp.full((F,), -1, jnp.int32).at[:D].set(nbr[v])
        fw = jnp.full((F,), INF).at[:D].set(nbw[v])
        feid = jnp.full((F,), -1, jnp.int32).at[:D].set(nbe[v])
        out = jnp.full((capacity,), -1, jnp.int32)
        st = dict(visited=visited, vcount=jnp.int32(1), fdst=fdst, fw=fw,
                  feid=feid, fsize=jnp.int32(D), out=out, ocount=jnp.int32(0),
                  hook=jnp.int32(-1), case=jnp.int32(0), queries=jnp.int32(1))

        def cond(s):
            return s["case"] == 0

        def body(s):
            idx = jnp.argmin(s["fw"])
            best_w = s["fw"][idx]
            dst = s["fdst"][idx]
            eid = s["feid"][idx]
            exhausted = jnp.isinf(best_w)
            # consume the frontier entry
            fw = s["fw"].at[idx].set(INF)
            fdst = s["fdst"].at[idx].set(-1)
            already = (s["visited"] == dst).any()
            lower = rank[jnp.clip(dst, 0, n - 1)] < rank[v]
            room = s["vcount"] < budget

            def on_exhausted(s):
                return {**s, "case": jnp.int32(2), "fw": fw, "fdst": fdst}

            def on_seen(s):
                return {**s, "fw": fw, "fdst": fdst}

            def on_hook(s):
                out = s["out"].at[s["ocount"]].set(eid)
                return {**s, "fw": fw, "fdst": fdst, "out": out,
                        "ocount": s["ocount"] + 1, "hook": dst,
                        "case": jnp.int32(3), "queries": s["queries"] + 1}

            def on_add(s):
                visited = s["visited"].at[s["vcount"]].set(dst)
                out = s["out"].at[s["ocount"]].set(eid)
                pos = s["fsize"]
                fdst2 = jax.lax.dynamic_update_slice(fdst, nbr[dst], (pos,))
                fw2 = jax.lax.dynamic_update_slice(fw, nbw[dst], (pos,))
                feid2 = jax.lax.dynamic_update_slice(s["feid"], nbe[dst], (pos,))
                vcount = s["vcount"] + 1
                case = jnp.where(vcount >= budget, jnp.int32(1), jnp.int32(0))
                return {**s, "visited": visited, "vcount": vcount,
                        "fdst": fdst2, "fw": fw2, "feid": feid2,
                        "fsize": pos + D, "out": out, "ocount": s["ocount"] + 1,
                        "case": case, "queries": s["queries"] + 1}

            branch = jnp.where(exhausted, 0,
                               jnp.where(already, 1, jnp.where(lower, 2, 3)))
            return jax.lax.switch(branch, [on_exhausted, on_seen, on_hook, on_add], s)

        s = jax.lax.while_loop(cond, body, st)
        return s["out"], s["hook"], s["case"], s["queries"]

    return jax.vmap(per_vertex)(jnp.arange(n, dtype=jnp.int32))


@functools.partial(jax.jit, static_argnames=("budget",))
def truncated_prim(nbr, nbw, nbe, rank, budget: int):
    """Run rank-truncated Prim from every vertex of a Δ<=3 graph.

    nbr/nbw/nbe: (n, D) padded adjacency (ids / weights / edge ids), -1 / inf pad.
    rank: (n,) distinct float ranks (the random permutation π).
    Returns (out_eids (n, budget), hooks (n,), cases (n,), queries (n,)).
    cases: 1 = budget hit, 2 = component exhausted, 3 = lower-rank hook.
    """
    return truncated_prim_capped(nbr, nbw, nbe, rank,
                                 jnp.int32(budget), budget)


# --------------------------------------------------------------------------
# Proposition 3.2: forest contraction by pointer jumping (in-round)
# --------------------------------------------------------------------------
@jax.jit
def pointer_jump(parent: jnp.ndarray):
    """Iterated doubling to the root; returns (roots, num_doublings)."""
    def cond(s):
        p, _ = s
        return jnp.any(p[p] != p)

    def body(s):
        p, it = s
        nxt = p[p]
        # gate the counter on actual progress so a vmapped lane that has
        # already converged stops counting (sequentially the body only runs
        # while cond holds, so the gate is a no-op there)
        return nxt, it + jnp.any(nxt != p).astype(jnp.int32)

    p, iters = jax.lax.while_loop(cond, body, (parent, jnp.int32(0)))
    return p, iters


# --------------------------------------------------------------------------
# Contraction: relabel edges, drop self-loops, dedup (min weight per pair)
# --------------------------------------------------------------------------
@jax.jit
def contract_edges(u, v, w, eid, valid, labels):
    """Relabel endpoints by ``labels``; self-loops invalidated; duplicate
    (cu, cv) pairs keep only the minimum-weight edge. Shapes are static; a
    boolean ``valid`` mask tracks liveness.  Returns (cu, cv, w, eid, valid,
    n_live_vertices)."""
    cu = labels[u]
    cv = labels[v]
    lo = jnp.minimum(cu, cv)
    hi = jnp.maximum(cu, cv)
    valid = valid & (lo != hi)
    big = jnp.int32(jnp.iinfo(jnp.int32).max)
    klo = jnp.where(valid, lo, big)
    khi = jnp.where(valid, hi, big)
    order = jnp.lexsort((w, khi, klo))
    slo, shi = klo[order], khi[order]
    first = jnp.concatenate([jnp.ones((1,), bool),
                             (slo[1:] != slo[:-1]) | (shi[1:] != shi[:-1])])
    keep = jnp.zeros_like(valid).at[order].set(first) & valid
    # live vertex count: labels that appear as an endpoint of a live edge
    live = jnp.zeros(labels.shape[0], jnp.int32)
    live = live.at[jnp.where(keep, lo, 0)].max(keep.astype(jnp.int32), mode="drop")
    live = live.at[jnp.where(keep, hi, 0)].max(keep.astype(jnp.int32), mode="drop")
    return cu, cv, w, eid, keep, live.sum()


# --------------------------------------------------------------------------
# DenseMSF stand-in: in-round Borůvka (min-edge hooking + doubling)
# --------------------------------------------------------------------------
def _component_min_edge(lu, lv, w, eid, valid, n):
    """For each component label, the (weight, lane)-lexicographic minimum
    incident cross edge.  Lanes (edge positions) are unique even when edge
    ids repeat (ternarization dummy edges all carry eid=-1), so the choice is
    unambiguous and two components hooking each other always agree on the
    same edge.  Returns (min_eid (n,), partner (n,), has (n,))."""
    E = w.shape[0]
    cross = valid & (lu != lv)
    wbig = jnp.where(cross, w, INF)
    both_l = jnp.concatenate([lu, lv])
    seg_w = jax.ops.segment_min(jnp.concatenate([wbig, wbig]), both_l,
                                num_segments=n)
    lane = jnp.arange(E, dtype=jnp.int32)
    big = jnp.int32(2**30)
    lane_u = jnp.where(cross & (w <= seg_w[lu]), lane, big)
    lane_v = jnp.where(cross & (w <= seg_w[lv]), lane, big)
    seg_lane = jax.ops.segment_min(jnp.concatenate([lane_u, lane_v]), both_l,
                                   num_segments=n)
    has = seg_lane < big
    sl = jnp.clip(seg_lane, 0, E - 1)
    min_eid = jnp.where(has, eid[sl], -1)
    comp = jnp.arange(n, dtype=jnp.int32)
    plu, plv = lu[sl], lv[sl]
    partner = jnp.where(plu == comp, plv, plu)
    partner = jnp.where(has, partner, comp)
    return min_eid, partner, has


def boruvka_core(u, v, w, eid, valid, n_labels: int, max_eid: int):
    """Borůvka run to completion inside one program (while_loop).
    Traceable core — call inside other jitted programs; use
    ``boruvka_inround`` for a standalone launch.

    Returns (msf_mask over [0, max_eid), labels, phases)."""
    n = n_labels
    labels0 = jnp.arange(n, dtype=jnp.int32)
    mask0 = jnp.zeros((max_eid,), bool)

    def cond(s):
        labels, mask, it, done = s
        return ~done

    def body(s):
        labels, mask, it, done_prev = s
        lu, lv = labels[u], labels[v]
        min_eid, partner, has = _component_min_edge(lu, lv, w, eid, valid, n)
        parent = jnp.where(has, partner, labels0)
        # break 2-cycles: keep the hook only on the smaller label
        two = (parent[parent] == labels0) & (parent != labels0)
        parent = jnp.where(two & (labels0 > parent), labels0, parent)
        roots, _ = pointer_jump(parent)
        # an edge is selected if it was some component's min edge; invalid
        # lanes (no edge / dummy eid=-1) scatter out-of-bounds and are dropped
        sel = jnp.where(has & (min_eid >= 0), min_eid, max_eid)
        selected_mask = jnp.zeros((max_eid,), bool).at[sel].set(True, mode="drop")
        mask = mask | selected_mask
        labels = roots[labels]
        done = ~jnp.any(has)
        # gate the phase counter on the carried-in done flag: sequentially
        # cond guarantees done_prev is False (so the gate is a no-op), but
        # under vmap a finished lane keeps executing the body until the
        # slowest lane converges and must stop counting phases
        return labels, mask, it + (~done_prev).astype(jnp.int32), done

    labels, mask, phases, _ = jax.lax.while_loop(
        cond, body, (labels0, mask0, jnp.int32(0), jnp.asarray(False)))
    return mask, labels, phases


boruvka_inround = functools.partial(jax.jit, static_argnames=("n_labels", "max_eid"))(
    boruvka_core)


# --------------------------------------------------------------------------
# MPC baseline: red/blue Borůvka, 3 shuffles per phase (paper Section 5.5)
# --------------------------------------------------------------------------
@jax.jit
def _mpc_boruvka_phase(u, v, w, eid, valid, labels, color, max_eid_mask):
    """One red/blue Borůvka phase (paper Section 5.5): each *blue* component
    computes its overall minimum incident cross edge and contracts into the
    partner only if the partner is *red*."""
    n = labels.shape[0]
    lu, lv = labels[u], labels[v]
    min_eid, partner, has = _component_min_edge(lu, lv, w, eid, valid, n)
    ids = jnp.arange(n, dtype=jnp.int32)
    hook = has & color[ids] & ~color[partner]        # I am blue, partner red
    parent = jnp.where(hook, partner, ids)           # depth 1, acyclic
    sel = jnp.where(hook & (min_eid >= 0), min_eid, max_eid_mask.shape[0])
    selected = jnp.zeros_like(max_eid_mask).at[sel].set(True, mode="drop")
    labels = parent[labels]
    new_valid = valid & (labels[u] != labels[v])
    remaining = new_valid.sum()
    return labels, selected, new_valid, remaining




# --------------------------------------------------------------------------
# Deprecated shims — the drivers moved to repro.ampc.solvers; prefer
# AmpcEngine().solve(g, "msf") / .solve(g, "msf-mpc").
# --------------------------------------------------------------------------
def msf_ampc(g: UGraph, epsilon: float = 0.5, seed: int = 0,
             ledger: Optional[RoundLedger] = None,
             skip_ternarize_if_dense: bool = True) -> Tuple[np.ndarray, dict]:
    """Deprecated shim over repro.ampc.solvers.msf_ampc."""
    from ..ampc import solvers
    from ..ampc.deprecation import warn_once
    warn_once("repro.core.msf.msf_ampc", 'AmpcEngine().solve(g, "msf")')
    return solvers.msf_ampc(g, epsilon=epsilon, seed=seed, ledger=ledger,
                            skip_ternarize_if_dense=skip_ternarize_if_dense)


def msf_mpc_boruvka(g: UGraph, seed: int = 0,
                    ledger: Optional[RoundLedger] = None,
                    max_phases: int = 200) -> Tuple[np.ndarray, dict]:
    """Deprecated shim over repro.ampc.solvers.msf_mpc_boruvka."""
    from ..ampc import solvers
    from ..ampc.deprecation import warn_once
    warn_once("repro.core.msf.msf_mpc_boruvka",
              'AmpcEngine().solve(g, "msf-mpc")')
    return solvers.msf_mpc_boruvka(g, seed=seed, ledger=ledger,
                                   max_phases=max_phases)
