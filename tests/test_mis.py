"""MIS: AMPC + MPC implementations compute the exact LFMIS (Section 5.3)."""
import numpy as np
import pytest

from repro.graph import generators as gen
from repro.core import mis, oracle
from repro.core.rounds import RoundLedger

FAMILIES = [
    ("er", lambda: gen.erdos_renyi(300, 6.0, seed=2)),
    ("rmat", lambda: gen.rmat(9, 8.0, seed=3)),
    ("grid", lambda: gen.grid2d(14, 13)),
    ("star", lambda: gen.star(50)),
]


@pytest.mark.parametrize("name,make", FAMILIES)
def test_mis_ampc_is_lfmis(name, make):
    g = make()
    got, st = mis.mis_ampc(g, seed=4)
    rng = np.random.default_rng(4)
    want = oracle.greedy_mis(g, rng.permutation(g.n).astype(np.float32))
    assert np.array_equal(got, want)


@pytest.mark.parametrize("name,make", FAMILIES[:2])
def test_mis_mpc_rootset(name, make):
    g = make()
    got, st = mis.mis_mpc_rootset(g, seed=4)
    rng = np.random.default_rng(4)
    want = oracle.greedy_mis(g, rng.permutation(g.n).astype(np.float32))
    assert np.array_equal(got, want)


def test_same_randomness_same_mis():
    """Paper: 'By specifying the same source of randomness, both the MPC and
    AMPC algorithms compute the same MIS.'"""
    g = gen.rmat(9, 6.0, seed=5)
    a, _ = mis.mis_ampc(g, seed=11)
    b, _ = mis.mis_mpc_rootset(g, seed=11)
    assert np.array_equal(a, b)


def test_shuffle_counts_table3():
    """AMPC MIS: 2 shuffles (1 heavy); MPC: 2 per phase, 8+ total."""
    g = gen.rmat(9, 8.0, seed=1)
    la = RoundLedger("ampc_mis")
    mis.mis_ampc(g, seed=0, ledger=la)
    assert la.shuffles == 2
    lm = RoundLedger("mpc_mis")
    _, st = mis.mis_mpc_rootset(g, seed=0, ledger=lm)
    assert lm.shuffles == 2 * st["phases"] and lm.shuffles >= 8


def test_caching_savings_factor():
    """Fig 4: caching reduces KV bytes by ~2-12x on skewed graphs."""
    g = gen.rmat(10, 12.0, seed=6)
    _, st = mis.mis_ampc(g, seed=0)
    assert st["cache_savings_factor"] > 1.2


def test_fixpoint_iters_log_n():
    """Fischer–Noever: O(log n) dependency depth w.h.p."""
    g = gen.erdos_renyi(2000, 8.0, seed=7)
    _, st = mis.mis_ampc(g, seed=0)
    assert st["fixpoint_iters"] <= 6 * np.log2(g.n)
