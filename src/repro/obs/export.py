"""Trace/metrics exporters: Chrome-trace JSON, JSONL, plain text.

* :func:`to_chrome_trace` — the Trace Event Format consumed by
  ``chrome://tracing`` and https://ui.perfetto.dev: complete events
  (``ph="X"`` with ``ts``/``dur`` in microseconds) for spans, instant
  events (``ph="i"``) for span events, and ``ph="M"`` metadata records.
* :func:`write_jsonl` — one JSON object per span (flat, parent-linked),
  for ad-hoc ``jq``/pandas analysis of engine timelines.
* :func:`metrics_report` — plain-text registry dump
  (``engine.metrics_report()`` delegates here).
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Dict, Iterable, List, Optional

from .trace import Span


def _roots(tracer_or_spans) -> List[Span]:
    if hasattr(tracer_or_spans, "spans"):
        return tracer_or_spans.spans()
    return list(tracer_or_spans)


def iter_spans(tracer_or_spans) -> Iterable[Span]:
    """Every span (roots + descendants), depth-first."""
    for root in _roots(tracer_or_spans):
        yield from root.walk()


def _args(attrs: Dict[str, Any]) -> Dict[str, Any]:
    # Chrome trace args must be JSON-serializable; stringify anything fancy
    out = {}
    for k, v in attrs.items():
        if isinstance(v, (str, int, float, bool)) or v is None:
            out[k] = v
        else:
            out[k] = str(v)
    return out


def to_chrome_trace(tracer_or_spans, *, pid: Optional[int] = None,
                    extra_meta: Optional[Dict[str, Any]] = None) -> dict:
    """Render spans as a Chrome-trace (Perfetto-loadable) JSON object.

    Returns ``{"traceEvents": [...], "displayTimeUnit": "ms", ...meta}``;
    pass the result to ``json.dump`` or use :func:`write_chrome_trace`.
    """
    pid = os.getpid() if pid is None else pid
    events: List[dict] = []
    tids = set()
    for sp in iter_spans(tracer_or_spans):
        tid = sp.thread_id or 0
        tids.add(tid)
        events.append({
            "name": sp.name, "ph": "X", "ts": sp.ts_us, "dur": sp.dur_us,
            "pid": pid, "tid": tid, "cat": "span",
            "args": _args(sp.attributes),
        })
        for ev in sp.events:
            events.append({
                "name": ev.name, "ph": "i", "ts": ev.ts_us, "pid": pid,
                "tid": tid, "s": "t", "cat": ev.level,
                "args": _args(ev.attributes),
            })
    if hasattr(tracer_or_spans, "orphan_events"):
        for ev in tracer_or_spans.orphan_events():
            events.append({"name": ev.name, "ph": "i", "ts": ev.ts_us,
                           "pid": pid, "tid": 0, "s": "p", "cat": ev.level,
                           "args": _args(ev.attributes)})
    main_tid = threading.main_thread().ident
    for tid in sorted(tids):
        label = "main" if tid == main_tid else f"thread-{tid}"
        events.append({"name": "thread_name", "ph": "M", "pid": pid,
                       "tid": tid, "args": {"name": label}})
    doc = {"traceEvents": events, "displayTimeUnit": "ms"}
    if extra_meta:
        doc["otherData"] = dict(extra_meta)
    return doc


def write_chrome_trace(path: str, tracer_or_spans, *,
                       extra_meta: Optional[Dict[str, Any]] = None) -> dict:
    """Write the Chrome-trace JSON to ``path``; returns the document."""
    doc = to_chrome_trace(tracer_or_spans, extra_meta=extra_meta)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


def span_to_dict(sp: Span, parent_id: Optional[int] = None) -> dict:
    return {
        "span_id": sp.span_id, "parent_id": parent_id, "name": sp.name,
        "ts_us": sp.ts_us, "dur_us": sp.dur_us, "thread_id": sp.thread_id,
        "attributes": _args(sp.attributes),
        "events": [{"name": ev.name, "ts_us": ev.ts_us, "level": ev.level,
                    "attributes": _args(ev.attributes)} for ev in sp.events],
    }


def write_jsonl(path: str, tracer_or_spans) -> int:
    """Write one JSON object per span; returns the number of lines."""
    n = 0
    with open(path, "w") as f:
        stack = [(root, None) for root in reversed(_roots(tracer_or_spans))]
        while stack:
            sp, parent_id = stack.pop()
            f.write(json.dumps(span_to_dict(sp, parent_id)) + "\n")
            n += 1
            for c in reversed(sp.children):
                stack.append((c, sp.span_id))
    return n


def coverage(tracer_or_spans, wall_us: float) -> float:
    """Fraction of ``wall_us`` covered by root spans (for the ≥95% gate)."""
    covered = sum(sp.dur_us for sp in _roots(tracer_or_spans))
    return covered / wall_us if wall_us > 0 else 0.0


def metrics_report(registry) -> str:
    """Plain-text metrics dump (``None``-safe)."""
    if registry is None:
        return "(metrics disabled)"
    return registry.report()
