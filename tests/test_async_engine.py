"""Async engine battery: determinism under concurrency, fault injection,
stress/shutdown, and GraphSession snapshot reuse.

The concurrency claims the engine makes are only trustworthy under load:
``submit`` results must be bit-identical to sequential ``solve`` for every
batch-safe problem on both DHT backends, futures resolved out of submission
order must still carry their own solve's ledger, injected transient faults
must retry on the owning future's span (and exhaust into the original
exception without wedging the pool), and a storm of submits + random
cancellations + a mid-stream ``shutdown(drain=True)`` must neither deadlock
nor drop or duplicate a result.
"""
import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.ampc import AmpcEngine, SNAPSHOT_PROBLEMS, get_problem
from repro.ampc.async_engine import CancelledError, FutureTimeout
from repro.graph import generators as gen
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer
from repro.runtime.retry import inject_transients

BACKENDS = ["local", "routed"]
# every problem with a registered batch adapter — the batch-safe set
BATCH_SAFE = ["mis", "matching", "weighted-matching", "vertex-cover",
              "connectivity", "one-vs-two"]

# ledger keys that must match between async and sequential solves of the
# same problem (wall/phase times legitimately differ per run)
LEDGER_KEYS = ("algorithm", "shuffles", "bytes_shuffled", "dht_queries",
               "dht_bytes", "dht_query_waves", "dedup_savings",
               "dht_overflows")


def _input_for(name):
    spec = get_problem(name)
    if spec.needs_cycles:
        return gen.two_cycles(40)
    g = gen.erdos_renyi(80, 3.0, seed=2)
    return g.with_random_weights(3) if spec.needs_weights else g


def _assert_same_output(a, b):
    if isinstance(a, np.ndarray):
        assert np.array_equal(a, b)
    else:
        assert a == b


# =========================================================================
# determinism under concurrency
# =========================================================================
@pytest.mark.parametrize("backend", BACKENDS)
def test_submit_bit_identical_to_solve(backend):
    """All batch-safe problems in flight at once == their sequential runs."""
    with AmpcEngine(dht_backend=backend, seed=0, max_workers=4) as eng:
        futures = {name: eng.submit(_input_for(name), name)
                   for name in BATCH_SAFE}
        sequential = {name: eng.solve(_input_for(name), name)
                      for name in BATCH_SAFE}
        for name, fut in futures.items():
            res = fut.result(timeout=300)
            _assert_same_output(res.output, sequential[name].output)
            assert res.problem == sequential[name].problem
            assert res.backend == backend


def test_out_of_order_results_keep_their_ledgers():
    """Futures read in reverse submission order still attribute the right
    per-solve ledger (shuffle/query accounting is per future, not FIFO)."""
    with AmpcEngine(seed=0, max_workers=3) as eng:
        futs = [eng.submit(_input_for(name), name) for name in BATCH_SAFE]
        seq = {name: eng.solve(_input_for(name), name)
               for name in BATCH_SAFE}
        for name, fut in reversed(list(zip(BATCH_SAFE, futs))):
            res = fut.result(timeout=300)
            for k in LEDGER_KEYS:
                assert res.ledger[k] == seq[name].ledger[k], \
                    f"{name}: ledger[{k!r}] diverged async vs sequential"
            assert res.stats["async"]["future"] == fut.future_id


def test_submit_many_parity_and_backpressure():
    """submit_many under a tiny bounded queue: backpressure paces the
    producer but every future still resolves with the sequential output."""
    graphs = [gen.erdos_renyi(60, 3.0, seed=s) for s in range(6)]
    with AmpcEngine(seed=0, max_workers=1, queue_depth=1) as eng:
        futs = eng.submit_many(graphs, "mis")
        want = [eng.solve(g, "mis") for g in graphs]
        for fut, w in zip(futs, want):
            assert np.array_equal(fut.result(timeout=300).output, w.output)


def test_deadline_missed_in_queue_times_out():
    with AmpcEngine(seed=0, max_workers=1) as eng:
        fut = eng.submit(_input_for("mis"), "mis", timeout=-1.0)
        with pytest.raises(FutureTimeout):
            fut.result(timeout=60)


def test_cancel_semantics():
    """cancel() wins only while queued; either way the future is coherent."""
    g = _input_for("mis")
    with AmpcEngine(seed=0, max_workers=1) as eng:
        blocker = eng.submit(g, "mis")          # occupies the single worker
        target = eng.submit(g, "mis")
        won = target.cancel()
        assert target.cancel() is False or won  # second cancel never "wins"
        if won:
            assert target.cancelled() and target.done()
            with pytest.raises(CancelledError):
                target.result(timeout=60)
        else:  # solve already started; it must complete normally
            assert np.array_equal(target.result(timeout=300).output,
                                  eng.solve(g, "mis").output)
        blocker.result(timeout=300)


# =========================================================================
# fault injection through runtime/retry
# =========================================================================
def test_injected_transient_retries_and_succeeds():
    g = _input_for("matching")
    with AmpcEngine(seed=0) as eng:
        want = eng.solve(g, "matching")
        with inject_transients(marker="preempted", times=1):
            res = eng.submit(g, "matching").result(timeout=300)
        assert np.array_equal(res.output, want.output)
        # the result's ledger describes exactly the successful attempt
        for k in LEDGER_KEYS:
            assert res.ledger[k] == want.ledger[k]


def test_retry_metric_and_warn_event_on_owning_span():
    reg = MetricsRegistry()
    tracer = Tracer()
    g = _input_for("mis")
    # retry reports to the *process* registry by design; read the delta
    from repro.obs.metrics import default_registry
    ctr = default_registry().counter("retry_transients_total",
                                     labelnames=("marker",))
    before = ctr.value(marker="RESOURCE_EXHAUSTED")
    with AmpcEngine(seed=0, trace=tracer, metrics=reg) as eng:
        with inject_transients(marker="RESOURCE_EXHAUSTED", times=1):
            fut = eng.submit(g, "mis")
            res = fut.result(timeout=300)
    assert ctr.value(marker="RESOURCE_EXHAUSTED") == before + 1
    span = res.trace
    assert span.name == "solve[async]"
    assert span.attributes["future"] == fut.future_id
    warns = [e for e in span.events if e.name == "transient_retry"]
    assert len(warns) == 1 and warns[0].level == "WARN"
    assert warns[0].attributes["marker"] == "RESOURCE_EXHAUSTED"
    # the queue wait is an event on the same owning span
    assert [e.name for e in span.events if e.name == "queue_wait"]


def test_exhausted_retries_surface_original_error_without_wedging():
    g = _input_for("mis")
    with AmpcEngine(seed=0) as eng:
        want = eng.solve(g, "mis")
        with inject_transients(marker="preempted", times=10):
            fut = eng.submit(g, "mis", retries=2)
            with pytest.raises(ValueError, match="injected transient"):
                fut.result(timeout=300)
        assert fut.done() and not fut.cancelled()
        # pool still serves: the very next submit resolves normally
        res = eng.submit(g, "mis").result(timeout=300)
        assert np.array_equal(res.output, want.output)


# =========================================================================
# stress: threads x submits x cancellations x mid-stream shutdown
# =========================================================================
def test_stress_no_deadlock_no_drops_inflight_returns_to_zero():
    N_THREADS, M_SUBMITS = 4, 6
    reg = MetricsRegistry()
    graphs = {s: gen.erdos_renyi(48, 3.0, seed=s) for s in range(4)}
    eng = AmpcEngine(seed=0, metrics=reg, max_workers=3, queue_depth=2)
    expected = {s: eng.solve(g, "mis").output for s, g in graphs.items()}
    collected = []        # (graph_seed, future)
    refused = []          # submits rejected by the closing engine
    lock = threading.Lock()

    def producer(tid):
        rng = np.random.default_rng(tid)
        for i in range(M_SUBMITS):
            s = int(rng.integers(len(graphs)))
            try:
                fut = eng.submit(graphs[s], "mis")
            except RuntimeError:
                with lock:
                    refused.append((tid, i))
                continue
            if rng.random() < 0.3:
                fut.cancel()
            with lock:
                collected.append((s, fut))

    threads = [threading.Thread(target=producer, args=(t,))
               for t in range(N_THREADS)]
    t0 = time.monotonic()
    for t in threads:
        t.start()
    time.sleep(0.05)                      # let the storm develop
    eng.shutdown(drain=True, timeout=300)  # forced mid-stream
    for t in threads:
        t.join(timeout=300)
        assert not t.is_alive(), "producer wedged on a shut-down engine"

    seen = set()
    for s, fut in collected:
        assert id(fut) not in seen, "duplicated future"
        seen.add(id(fut))
        try:
            res = fut.result(timeout=300)   # bounded: no deadlock
        except CancelledError:
            assert fut.cancelled()
            continue
        assert np.array_equal(res.output, expected[s]), \
            "result attributed to the wrong graph"
    assert time.monotonic() - t0 < 600, "stress test exceeded wall bound"
    # every accepted future reached a terminal state -> gauge back to 0
    assert reg.gauge("engine_async_inflight").value() == 0
    # a submit refused while blocked on a full queue was already counted
    # (and then cancelled), so submitted sits between the two bounds
    submitted = reg.counter("engine_async_submitted_total",
                            labelnames=("problem",)).value(problem="mis")
    assert len(collected) <= submitted <= len(collected) + len(refused)
    assert len(collected) + len(refused) == N_THREADS * M_SUBMITS


def test_shutdown_drain_false_cancels_queued():
    g = _input_for("mis")
    reg = MetricsRegistry()
    with AmpcEngine(seed=0, metrics=reg, max_workers=1,
                    queue_depth=8) as eng:
        futs = [eng.submit(g, "mis") for _ in range(5)]
        eng.shutdown(drain=False, timeout=300)
        outcomes = {"done": 0, "cancelled": 0}
        for fut in futs:
            try:
                fut.result(timeout=300)
                outcomes["done"] += 1
            except CancelledError:
                outcomes["cancelled"] += 1
        assert outcomes["done"] + outcomes["cancelled"] == 5
        assert reg.gauge("engine_async_inflight").value() == 0
        cancelled = reg.counter("engine_async_cancelled_total",
                                labelnames=("problem",)).value(problem="mis")
        assert cancelled == outcomes["cancelled"]
    with pytest.raises(RuntimeError):
        eng.submit(g, "mis")
    eng.shutdown()  # idempotent


# =========================================================================
# GraphSession snapshot reuse
# =========================================================================
def test_session_snapshot_hit_skips_writekv_shuffle():
    g = gen.erdos_renyi(80, 3.0, seed=2)
    tracer = Tracer()
    with AmpcEngine(seed=0, trace=tracer) as eng:
        sess = eng.session(g)
        cold = sess.solve("mis")
        warm = sess.solve("matching")
        warm2 = sess.solve("vertex-cover")
    assert cold.stats["snapshot"] == {"hit": False, "key": sess.key,
                                      "supported": True}
    assert warm.stats["snapshot"]["hit"] and warm2.stats["snapshot"]["hit"]
    # ledger: the cold solve pays the WriteGraphKV shuffle, warm solves
    # skip the rebuild entirely (1 shuffle instead of the sequential 2)
    assert cold.ledger["shuffles"] == 2
    assert warm.ledger["shuffles"] == 1 and warm2.ledger["shuffles"] == 1
    # span structure agrees with the ledger counts
    assert [c.name for c in cold.trace.children
            if c.name.startswith("shuffle:")][0] == "shuffle:WriteGraphKV"
    warm_shuffles = [c.name for c in warm.trace.children
                     if c.name.startswith("shuffle:")]
    assert warm_shuffles == ["shuffle:IsInMM"]
    info = eng.cache_info(kind="snapshot")
    assert (info.misses, info.hits, info.size) == (1, 2, 1)


def test_session_invalidate_rebuilds():
    g = gen.erdos_renyi(60, 3.0, seed=3)
    with AmpcEngine(seed=0) as eng:
        sess = eng.session(g)
        sess.solve("mis")
        assert sess.invalidate() == 1
        res = sess.solve("matching")
        assert res.stats["snapshot"]["hit"] is False
        assert res.ledger["shuffles"] == 2
        assert sess.invalidate() == 1 and sess.invalidate() == 0


def test_session_unsupported_problem_passes_through():
    g = gen.erdos_renyi(60, 3.0, seed=3)
    with AmpcEngine(seed=0) as eng:
        res = eng.session(g).solve("matching-levels")
        assert res.stats["snapshot"] == {"hit": False, "supported": False}
        want = eng.solve(g, "matching-levels")
        assert np.array_equal(res.output, want.output)
    # every Table-3 core problem is snapshot-aware now; the multi-launch
    # variants are not
    assert "msf" in SNAPSHOT_PROBLEMS
    assert "matching-levels" not in SNAPSHOT_PROBLEMS


def test_session_async_submit_shares_snapshot():
    g = gen.erdos_renyi(60, 3.0, seed=4)
    with AmpcEngine(seed=0) as eng:
        sess = eng.session(g)
        sess.solve("mis")                         # materialize
        res = sess.submit("matching").result(timeout=300)
        assert res.stats["snapshot"]["hit"] is True
        assert np.array_equal(res.output, eng.solve(g, "matching").output)


# one-vs-two needs a union-of-cycles input, so it gets its own session
# test below; everything else shares one weighted ER graph
SESSION_PROBLEMS = sorted(SNAPSHOT_PROBLEMS - {"one-vs-two"})


@settings(max_examples=5, deadline=None)
@given(st.lists(st.integers(0, len(SESSION_PROBLEMS) - 1),
                min_size=1, max_size=5))
def test_property_session_equals_fresh_engine(seq):
    """Any sequence of solves on one GraphSession == fresh-engine solves."""
    g = gen.erdos_renyi(50, 3.0, seed=7).with_random_weights(3)
    with AmpcEngine(seed=0) as eng:
        sess = eng.session(g)
        for idx in seq:
            name = SESSION_PROBLEMS[idx]
            got = sess.solve(name)
            want = AmpcEngine(seed=0).solve(g, name)
            assert np.array_equal(got.output, want.output)
            assert got.stats["snapshot"]["supported"] is True


def test_session_one_vs_two_equals_fresh_engine():
    g = gen.two_cycles(32)
    with AmpcEngine(seed=0) as eng:
        sess = eng.session(g)
        cold = sess.solve("one-vs-two", p=1 / 8)
        warm = sess.solve("one-vs-two", p=1 / 8)
        want = AmpcEngine(seed=0).solve(g, "one-vs-two", p=1 / 8)
    assert cold.output == warm.output == want.output == 2
    assert warm.stats["snapshot"]["hit"] and warm.ledger["shuffles"] == 1
