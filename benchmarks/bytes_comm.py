"""Figures 3 + 9 reproduction: bytes shuffled (MPC vs AMPC) and bytes of
KV-store (DHT) communication; linear trend of DHT bytes vs edges.  Solves go
through the AmpcEngine, so the ledger keys are the stable result surface."""
from __future__ import annotations

from repro.ampc import AmpcEngine

from .common import DEFAULT_GRAPHS, GRAPHS, fmt_table
from .registry import bench


@bench("bytes_comm", takes_graphs=True,
       quick_kwargs={"graph_names": ["rmat12", "er13"]},
       summary="Fig 3/9: shuffle + DHT bytes, AMPC vs MPC")
def run(graph_names=None):
    names = graph_names or list(DEFAULT_GRAPHS)
    eng = AmpcEngine(seed=0)
    rows = []
    trend = []
    for gname in names:
        g = GRAPHS[gname]()
        la = eng.solve(g, "mis").ledger
        lm = eng.solve(g, "mis-mpc").ledger
        rows.append([gname, g.n, g.m,
                     f"{la['bytes_shuffled']/1e6:.1f}",
                     f"{la['dht_bytes']/1e6:.1f}",
                     f"{lm['bytes_shuffled']/1e6:.1f}",
                     f"{lm['bytes_shuffled']/max(la['bytes_shuffled'],1):.1f}x"])
        trend.append((g.m, la["dht_bytes"]))
    out = fmt_table(["graph", "n", "m", "AMPC shuffle MB", "AMPC DHT MB",
                     "MPC shuffle MB", "MPC/AMPC shuffled"], rows)
    print(out)
    # Fig 9: DHT bytes scale linearly with edges
    import numpy as np
    ms = np.array([t[0] for t in trend], float)
    bs = np.array([t[1] for t in trend], float)
    corr = float(np.corrcoef(np.log(ms), np.log(bs))[0, 1])
    print(f"\nlog-log correlation(DHT bytes, edges) = {corr:.3f} "
          f"(paper Fig 9: consistent linear trend)")
    return {"rows": rows, "loglog_corr": corr, "markdown": out}


if __name__ == "__main__":
    run()
