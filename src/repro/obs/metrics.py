"""Engine-wide metrics: counters, gauges, histograms with labels.

A deliberately small prometheus-style registry (no external deps, no HTTP
endpoint): metrics are named, typed, and labeled; every observation is a
dict update under one lock, so recording from a threaded serving loop is
safe and cheap (~a dict lookup + add per observation).

:data:`ENGINE_METRICS` is the canonical table of every metric the engine
stack emits — the "Observability" section of ``docs/architecture.md``
renders this table and ``tests/test_docs.py`` asserts the two never drift.

Call sites hold a :class:`MetricsRegistry` (the engine's ``metrics=`` hook,
defaulting to the process-wide :func:`default_registry`) and do::

    registry.counter("dht_queries_total", labelnames=("algorithm",)) \\
            .inc(42, algorithm="ampc_mis")
    registry.histogram("solve_latency_s",
                       labelnames=("problem", "backend")) \\
            .observe(0.012, problem="mis", backend="local")
    print(registry.report())
"""
from __future__ import annotations

import bisect
import dataclasses
import threading
from typing import Dict, Optional, Tuple

# -----------------------------------------------------------------------
# Canonical metric table (docs/architecture.md renders this; test_docs
# asserts the rendered table matches).
# -----------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MetricDef:
    name: str
    kind: str                    # "counter" | "gauge" | "histogram"
    labels: Tuple[str, ...]
    help: str


ENGINE_METRICS: Dict[str, MetricDef] = {m.name: m for m in [
    MetricDef("solve_latency_s", "histogram", ("problem", "backend"),
              "end-to-end wall time of one solve (per graph in solve_many)"),
    MetricDef("solves_total", "counter", ("problem", "backend", "mode"),
              "engine solves served; mode=solve|solve_many"),
    MetricDef("shuffles_total", "counter", ("algorithm",),
              "materialized rounds recorded by RoundLedgers"),
    MetricDef("bytes_shuffled_total", "counter", ("algorithm",),
              "bytes written by materialized rounds"),
    MetricDef("dht_queries_total", "counter", ("algorithm",),
              "KV lookups issued against DHT snapshots (post-dedup)"),
    MetricDef("dht_bytes_total", "counter", ("algorithm",),
              "query + answer bytes on the DHT"),
    MetricDef("dht_query_waves_total", "counter", ("algorithm",),
              "adaptive query waves inside launches"),
    MetricDef("dedup_savings_total", "counter", ("algorithm",),
              "queries avoided by the per-machine caching optimization"),
    MetricDef("dht_overflows_total", "counter", ("algorithm",),
              "routed-router capacity overflows (0 = exact answers)"),
    MetricDef("solver_cache_hits_total", "counter", (),
              "graphs served by an already-traced batched solver"),
    MetricDef("solver_cache_misses_total", "counter", (),
              "batched solvers actually traced/compiled"),
    MetricDef("retry_transients_total", "counter", ("marker",),
              "transient launch failures retried by runtime.retry"),
    MetricDef("engine_async_submitted_total", "counter", ("problem",),
              "futures accepted by AmpcEngine.submit"),
    MetricDef("engine_async_cancelled_total", "counter", ("problem",),
              "futures cancelled before their solve started"),
    MetricDef("engine_async_inflight", "gauge", (),
              "submitted futures not yet resolved (0 when the pool is idle)"),
]}


# -----------------------------------------------------------------------
# Metric types
# -----------------------------------------------------------------------
class _Metric:
    kind = ""

    def __init__(self, name: str, help: str, labelnames: Tuple[str, ...],
                 lock: threading.Lock):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = lock
        self._values: Dict[Tuple, float] = {}

    def _key(self, labels: Dict[str, str]) -> Tuple:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"metric {self.name!r} expects labels {self.labelnames}, "
                f"got {tuple(sorted(labels))}")
        return tuple(str(labels[k]) for k in self.labelnames)

    def collect(self) -> Dict[Tuple, float]:
        with self._lock:
            return dict(self._values)

    def value(self, **labels) -> float:
        with self._lock:
            return self._values.get(self._key(labels), 0.0)


class Counter(_Metric):
    kind = "counter"

    def inc(self, value: float = 1, **labels) -> None:
        if value < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease")
        key = self._key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + value


class Gauge(_Metric):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        with self._lock:
            self._values[self._key(labels)] = float(value)

    def inc(self, value: float = 1, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + value


DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                   0.5, 1.0, 2.5, 5.0, 10.0, float("inf"))


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, name, help, labelnames, lock,
                 buckets: Tuple[float, ...] = DEFAULT_BUCKETS):
        super().__init__(name, help, labelnames, lock)
        b = tuple(sorted(buckets))
        if not b or b[-1] != float("inf"):
            b = b + (float("inf"),)
        self.buckets = b
        # per label-key: [count, sum, per-bucket cumulative-style counts]
        self._hist: Dict[Tuple, list] = {}

    def observe(self, value: float, **labels) -> None:
        key = self._key(labels)
        idx = bisect.bisect_left(self.buckets, value)
        with self._lock:
            h = self._hist.get(key)
            if h is None:
                h = self._hist[key] = [0, 0.0, [0] * len(self.buckets)]
            h[0] += 1
            h[1] += value
            h[2][idx] += 1
            self._values[key] = h[1]      # collect() → sum, like counters

    def stats(self, **labels) -> Dict[str, float]:
        with self._lock:
            h = self._hist.get(self._key(labels))
            if h is None:
                return {"count": 0, "sum": 0.0, "mean": 0.0}
            return {"count": h[0], "sum": h[1], "mean": h[1] / max(h[0], 1)}

    def collect_hist(self) -> Dict[Tuple, dict]:
        with self._lock:
            return {k: {"count": h[0], "sum": h[1],
                        "buckets": dict(zip(self.buckets, h[2]))}
                    for k, h in self._hist.items()}


# -----------------------------------------------------------------------
# Registry
# -----------------------------------------------------------------------
class MetricsRegistry:
    """Named, typed, labeled metrics under one lock.

    ``counter``/``gauge``/``histogram`` are get-or-create: repeated calls
    with the same name return the same metric (and raise on a kind or
    labelnames mismatch, so two call sites cannot silently diverge).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, _Metric] = {}

    def _get_or_create(self, cls, name: str, help: str,
                       labelnames: Tuple[str, ...], **kw) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help, labelnames,
                                              self._lock, **kw)
                return m
        if not isinstance(m, cls):
            raise ValueError(f"metric {name!r} already registered as "
                             f"{m.kind}, not {cls.kind}")
        if m.labelnames != tuple(labelnames):
            raise ValueError(f"metric {name!r} labelnames {m.labelnames} != "
                             f"{tuple(labelnames)}")
        return m

    def counter(self, name: str, help: str = "",
                labelnames: Tuple[str, ...] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Tuple[str, ...] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Tuple[str, ...] = (),
                  buckets: Tuple[float, ...] = DEFAULT_BUCKETS) -> Histogram:
        return self._get_or_create(Histogram, name, help, labelnames,
                                   buckets=buckets)

    # -- inspection --------------------------------------------------------
    def metrics(self) -> Dict[str, _Metric]:
        with self._lock:
            return dict(self._metrics)

    def as_dict(self) -> Dict[str, Dict[str, float]]:
        """``{metric: {"label=a,label2=b": value}}`` snapshot."""
        out = {}
        for name, m in sorted(self.metrics().items()):
            series = {}
            for key, val in sorted(m.collect().items()):
                label = ",".join(f"{k}={v}"
                                 for k, v in zip(m.labelnames, key))
                series[label] = val
            out[name] = series
        return out

    def report(self) -> str:
        """Plain-text report (the ``engine.metrics_report()`` payload)."""
        lines = []
        for name, m in sorted(self.metrics().items()):
            head = f"# {m.kind} {name}"
            if m.help:
                head += f" — {m.help}"
            lines.append(head)
            if isinstance(m, Histogram):
                for key, h in sorted(m.collect_hist().items()):
                    labels = _fmt_labels(m.labelnames, key)
                    mean = h["sum"] / max(h["count"], 1)
                    lines.append(f"{name}{labels}  count={h['count']} "
                                 f"sum={h['sum']:.6g} mean={mean:.6g}")
            else:
                for key, val in sorted(m.collect().items()):
                    v = int(val) if float(val).is_integer() else val
                    lines.append(f"{name}{_fmt_labels(m.labelnames, key)}  "
                                 f"{v}")
        return "\n".join(lines) if lines else "(no metrics recorded)"

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()

    def __repr__(self):
        return f"MetricsRegistry(metrics={sorted(self.metrics())})"


def _fmt_labels(names: Tuple[str, ...], key: Tuple) -> str:
    if not names:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in zip(names, key)) + "}"


_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-wide registry (engine default; runtime.retry reports
    here too, so one report covers the whole stack)."""
    return _DEFAULT


def as_registry(spec) -> Optional[MetricsRegistry]:
    """Resolve the engine's ``metrics=`` argument.

    ``None`` → :func:`default_registry`; ``False`` → metrics disabled
    (``None``); a :class:`MetricsRegistry` passes through.
    """
    if spec is None:
        return default_registry()
    if spec is False:
        return None
    if isinstance(spec, MetricsRegistry):
        return spec
    raise TypeError(f"metrics must be None/False/MetricsRegistry, "
                    f"got {type(spec)}")
