"""llama4-scout-17b-a16e: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 + shared expert."""
from .lm_archs import LLAMA4_SCOUT as CONFIG, smoke
SMOKE = smoke(CONFIG)
