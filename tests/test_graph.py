"""Graph substrate invariants (+ hypothesis)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.graph import generators as gen
from repro.graph.coo import UGraph
from repro.core.ternarize import ternarize
from repro.core import oracle


def test_dedup_removes_self_loops_and_parallels():
    e = np.array([[0, 1], [1, 0], [2, 2], [0, 1], [1, 2]], np.int32)
    g = UGraph(4, e, np.array([5.0, 3.0, 1.0, 2.0, 7.0], np.float32)).dedup()
    assert g.m == 2
    key = set(map(tuple, np.sort(g.edges, axis=1).tolist()))
    assert key == {(0, 1), (1, 2)}
    # min-weight kept for the parallel pair
    w01 = g.weights[[tuple(sorted(x)) == (0, 1) for x in g.edges.tolist()]]
    assert float(w01[0]) == 2.0


def test_csr_roundtrip():
    g = gen.erdos_renyi(50, 4.0, seed=0)
    indptr, indices, _, eid = g.csr()
    assert indptr[-1] == 2 * g.m
    deg = g.degrees()
    assert np.array_equal(np.diff(indptr), deg)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 30), st.floats(1.0, 6.0), st.integers(0, 100))
def test_ternarize_preserves_msf(n, avg_deg, seed):
    g = gen.erdos_renyi(max(n, 4), avg_deg, seed=seed).with_random_weights(seed)
    if g.m == 0:
        return
    tg = ternarize(g)
    assert tg.g.degrees().max() <= 3
    # MSF(tern) restricted to real edges == MSF(orig)
    mo, _ = oracle.kruskal_msf(g)
    mt, _ = oracle.kruskal_msf(tg.g)
    real = np.zeros(g.m, bool)
    sel = tg.orig_eid[mt & (tg.orig_eid >= 0)]
    real[sel] = True
    assert np.array_equal(mo, real)


def test_two_cycles_structure():
    g = gen.two_cycles(10)
    assert g.n == 20 and g.m == 20
    assert (g.degrees() == 2).all()
    assert oracle.num_components(g) == 2


def test_rmat_power_law_ish():
    g = gen.rmat(10, 8.0, seed=0)
    deg = g.degrees()
    assert deg.max() > 4 * deg.mean()  # heavy tail


def test_random_geometric_outputs():
    g, pos, species = gen.random_geometric(50, 1.5, seed=1)
    assert pos.shape == (50, 3) and species.shape == (50,)
    assert g.n == 50
