"""Corollary 4.1: applications of the AMPC maximal-matching black box.

  * (2+ε)-approximate maximum WEIGHT matching: greedy over edges in
    decreasing-weight order is a 1/2-approximation (Avis '83); running the
    AMPC greedy-MM fixpoint with weight-derived ranks computes exactly that
    greedy in O(1) adaptive rounds.
  * 2-approximate minimum vertex cover: the endpoints of any maximal
    matching.
  * (1+ε)-approximate maximum CARDINALITY matching is obtained by the
    standard augmenting-path boosting over O(1/ε) rounds of maximal
    matchings (we provide the single-round 1/2-approx building block).

Both functions are deprecated shims over ``repro.ampc.solvers``; the weight
ranks are injected through the public ``mm_ampc(erank=...)`` parameter (no
more inline-import monkey-wiring).  Prefer
``AmpcEngine().solve(g, "weighted-matching")`` / ``.solve(g, "vertex-cover")``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from ..graph.coo import UGraph
from .rounds import RoundLedger


def mwm_greedy_ampc(g: UGraph, seed: int = 0,
                    ledger: Optional[RoundLedger] = None
                    ) -> Tuple[np.ndarray, dict]:
    """Deprecated shim over repro.ampc.solvers.mwm_greedy_ampc."""
    from ..ampc import solvers
    from ..ampc.deprecation import warn_once
    warn_once("repro.core.weighted_matching.mwm_greedy_ampc",
              'AmpcEngine().solve(g, "weighted-matching")')
    return solvers.mwm_greedy_ampc(g, seed=seed, ledger=ledger)


def vertex_cover_2approx(g: UGraph, seed: int = 0,
                         ledger: Optional[RoundLedger] = None
                         ) -> Tuple[np.ndarray, dict]:
    """Deprecated shim over repro.ampc.solvers.vertex_cover_2approx."""
    from ..ampc import solvers
    from ..ampc.deprecation import warn_once
    warn_once("repro.core.weighted_matching.vertex_cover_2approx",
              'AmpcEngine().solve(g, "vertex-cover")')
    return solvers.vertex_cover_2approx(g, seed=seed, ledger=ledger)
