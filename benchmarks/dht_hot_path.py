"""DHT hot path: eager per-lookup syncs vs deferred one-harvest accounting.

The measurement behind the deferred-ledger redesign.  Three configurations
of the same work:

  * ``eager``            — ``deferred_accounting=False``: the seed hot
    path, preserved verbatim as the compatibility mode.  Every DHT lookup
    blocks the host twice (``valid`` before the gather dispatch,
    ``n_unique`` after it), the gather runs op-by-op, and every result
    materialization transfers leaf by leaf — the per-value
    ``int(device_get(...))`` pattern the solvers used to make.
  * ``deferred``         — the default: one fused XLA launch per lookup
    (gather + staged counters as extra outputs), ONE harvest transfer at
    result materialization.
  * ``deferred+pallas``  — deferred accounting with the cached-gather
    Pallas kernel (``impl="pallas"``) serving the snapshot reads.  On the
    CPU host the kernel runs interpreted, so this row is a functionality
    demonstration, not a speed claim; on TPU it is the compiled path.

Three scenarios, hot-path-bound first:

  1. **per-lookup serving loop** — independent ``ShardedDHT.lookup``
     batches against one snapshot; mean latency per lookup.  Isolates the
     per-lookup sync + dispatch cost.
  2. **adaptive wave solve** — pointer chasing: wave ``k+1``'s keys are
     wave ``k``'s answers, the paper's canonical adaptive in-round
     workload (hash-to-min / parent jumping, the shape Theorem 1's
     constant-adaptive-round algorithms repeat).  Warm wall time for a
     full multi-wave solve; this is the headline ``warm_solve_speedup``.
  3. **engine fixpoint solves** — median warm ``engine.solve`` wall time
     over the benchmark problems.  These run 1-3 accounting records per
     solve (the adaptive waves live *inside* one jitted fixpoint), so the
     deferral win is bounded by a few syncs per solve — reported
     transparently as ``engine_solve_speedup``, no headline claim.

Samples for scenarios 2 and 3 interleave the configs (eager, deferred,
eager, ...) so slow drift on a shared host cancels out of the ratio.

Emits ``BENCH_dht_hot_path.json`` with every sample plus the headline
``warm_solve_speedup`` (eager median / deferred median on the adaptive
wave solve).  The acceptance bar for the redesign is >= 1.5x.
"""
from __future__ import annotations

import json
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ampc import AmpcEngine
from repro.core.dht import ShardedDHT
from repro.core.rounds import RoundLedger
from repro.graph import generators as gen

from .common import fmt_table
from .registry import bench

OUT_JSON = "BENCH_dht_hot_path.json"
SESSION_JSON = "BENCH_session_reuse.json"   # companion: snapshot reuse


def _make_dht(n_vals: int, impl: str, deferred: bool):
    # values form a permutation so pointer chasing never leaves the keyspace
    parent = np.random.default_rng(7).permutation(n_vals).astype(np.int32)
    ledger = RoundLedger("bench", deferred=deferred)
    return ShardedDHT(jnp.asarray(parent), ledger=ledger, impl=impl), ledger


def _per_lookup(n_vals: int, n_keys: int, iters: int, impl: str,
                deferred: bool) -> float:
    """Mean seconds per ``ShardedDHT.lookup`` in a tight serving loop."""
    dht, ledger = _make_dht(n_vals, impl, deferred)
    keys = jnp.asarray(
        np.random.default_rng(0).integers(0, n_vals, n_keys), jnp.int32)
    dht.lookup(keys).block_until_ready()      # warm the compiled gather
    ledger.harvest()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = dht.lookup(keys)
    out.block_until_ready()                   # charge the pipeline drain
    elapsed = time.perf_counter() - t0
    ledger.harvest()
    return elapsed / iters


def _wave_solve(dht, ledger, keys, waves: int):
    """One adaptive multi-wave solve: answers of wave k are keys of k+1."""
    t0 = time.perf_counter()
    for _ in range(waves):
        keys = dht.lookup(keys)
    np.asarray(ledger.harvest(keys))          # result materialization
    return time.perf_counter() - t0


def _adaptive_waves(n_vals: int, n_keys: int, waves: int, repeats: int,
                    impl_deferred, impl_eager="take"):
    """Interleaved warm samples of the wave solve, eager vs deferred."""
    d_dht, d_led = _make_dht(n_vals, impl_deferred, deferred=True)
    e_dht, e_led = _make_dht(n_vals, impl_eager, deferred=False)
    keys0 = jnp.asarray(
        np.random.default_rng(1).integers(0, n_vals, n_keys), jnp.int32)
    _wave_solve(e_dht, e_led, keys0, waves)   # warm both paths
    _wave_solve(d_dht, d_led, keys0, waves)
    te, td = [], []
    for _ in range(repeats):
        te.append(_wave_solve(e_dht, e_led, keys0, waves))
        td.append(_wave_solve(d_dht, d_led, keys0, waves))
    assert e_led.summary()["dht_queries"] == d_led.summary()["dht_queries"]
    return te, td


def _engine_solves(graph, problems, repeats: int):
    """Interleaved warm ``engine.solve`` samples per problem."""
    out = {}
    for prob in problems:
        e = AmpcEngine(seed=0, deferred_accounting=False)
        d = AmpcEngine(seed=0)
        e.solve(graph, prob)                  # compile both engines
        d.solve(graph, prob)
        te, td = [], []
        for _ in range(repeats):
            t0 = time.perf_counter()
            e.solve(graph, prob)
            te.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            d.solve(graph, prob)
            td.append(time.perf_counter() - t0)
        out[prob] = {"eager": te, "deferred": td}
    return out


def _session_solves(graph, problems, repeats: int):
    """Interleaved warm ``GraphSession.solve`` vs plain ``engine.solve``.

    The snapshot-reuse claim for the ternarized views: a warm session
    ``msf`` / ``connectivity`` solve materializes 1 round (the fused
    algorithm shuffle) instead of rebuilding the ternarized KV image,
    while the plain solve pays the full sequential shuffle pipeline.
    """
    out = {}
    for prob in problems:
        eng = AmpcEngine(seed=0)
        sess = eng.session(graph)
        plain = eng.solve(graph, prob)        # compile the plain path
        sess.solve(prob)                      # cold: builds the view
        warm = sess.solve(prob)               # compile the fused warm path
        assert np.array_equal(np.asarray(warm.output),
                              np.asarray(plain.output))
        tp, tw = [], []
        for _ in range(repeats):
            t0 = time.perf_counter()
            plain = eng.solve(graph, prob)
            tp.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            warm = sess.solve(prob)
            tw.append(time.perf_counter() - t0)
        assert warm.stats["snapshot"]["hit"] is True
        out[prob] = {
            "plain_s": tp, "warm_session_s": tw,
            "plain_shuffles": plain.ledger["shuffles"],
            "warm_shuffles": warm.ledger["shuffles"],
            "shuffles_saved": (plain.ledger["shuffles"]
                               - warm.ledger["shuffles"]),
        }
    return out


@bench("dht_hot_path",
       quick_kwargs={"problems": ["mis", "matching"], "repeats": 12,
                     "lookup_iters": 150, "waves": 24},
       summary="eager vs deferred ledger accounting: per-lookup latency, "
               "adaptive wave solves, warm engine solve wall time")
def run(problems=None, n: int = 1024, degree: float = 4.0,
        repeats: int = 25, lookup_iters: int = 300, waves: int = 32):
    problems = problems or ["mis", "matching", "connectivity"]

    # -- scenario 1: per-lookup serving loop -----------------------------
    nv, nk = 1 << 15, 1 << 12
    lk = {
        "eager": _per_lookup(nv, nk, lookup_iters, "take", deferred=False),
        "deferred": _per_lookup(nv, nk, lookup_iters, "take", deferred=True),
        "deferred+pallas": _per_lookup(nv, nk, max(lookup_iters // 8, 5),
                                       "pallas", deferred=True),
    }
    print(fmt_table(
        ["config", "us/lookup", "vs eager"],
        [[name, f"{v * 1e6:8.1f}", f"{lk['eager'] / v:5.2f}x"]
         for name, v in lk.items()]))

    # -- scenario 2: adaptive wave solve (headline) ----------------------
    te, td = _adaptive_waves(nv, nk // 4, waves, repeats, "take")
    _, tp = _adaptive_waves(nv, nk // 4, waves, max(repeats // 4, 2),
                            "pallas")
    me, md, mp = (statistics.median(x) for x in (te, td, tp))
    headline = me / md
    print(fmt_table(
        ["adaptive wave solve", "ms/solve", "vs eager"],
        [["eager", f"{me * 1e3:8.2f}", " 1.00x"],
         ["deferred", f"{md * 1e3:8.2f}", f"{headline:5.2f}x"],
         ["deferred+pallas", f"{mp * 1e3:8.2f}", f"{me / mp:5.2f}x"]]))
    print(f"warm solve speedup (adaptive {waves}-wave solve): "
          f"{headline:.2f}x (bar: >= 1.50x)")

    # -- scenario 3: engine fixpoint solves (transparency) ---------------
    graph = gen.erdos_renyi(n, degree, seed=1)
    eng = _engine_solves(graph, problems, repeats)
    rows, eng_speedup = [], {}
    for prob in problems:
        pe = statistics.median(eng[prob]["eager"])
        pd = statistics.median(eng[prob]["deferred"])
        eng_speedup[prob] = pe / pd
        rows.append([prob, f"{pe * 1e3:8.2f}", f"{pd * 1e3:8.2f}",
                     f"{pe / pd:5.2f}x"])
    print(fmt_table(
        ["engine.solve", "eager ms", "deferred ms", "speedup"], rows))
    print("(fixpoint solves run their adaptive waves inside one jitted "
          "launch; 1-3 records/solve bounds the deferral win here)")

    # -- scenario 4: warm-session snapshot reuse (msf / connectivity) ----
    wg = gen.erdos_renyi(n, degree, seed=1).with_random_weights(seed=2)
    sess = _session_solves(wg, ["msf", "connectivity"], repeats)
    sess_rows = []
    for prob, rec in sess.items():
        mp_, mw = (statistics.median(rec["plain_s"]),
                   statistics.median(rec["warm_session_s"]))
        rec["warm_session_speedup"] = mp_ / mw
        sess_rows.append([prob, f"{mp_ * 1e3:8.2f}", f"{mw * 1e3:8.2f}",
                          f"{mp_ / mw:5.2f}x",
                          f"{rec['plain_shuffles']}->{rec['warm_shuffles']}"])
    print(fmt_table(["warm session", "plain ms", "session ms", "speedup",
                     "shuffles"], sess_rows))
    print("(warm session solves reuse the ternarized snapshot view: 1 "
          "materialized round instead of the full sequential pipeline)")
    session_doc = {
        "bench": "dht_hot_path/session_reuse",
        "host": {"backend": jax.default_backend(),
                 "devices": jax.device_count()},
        "graph": {"n": wg.n, "m": wg.m},
        "session": sess,
    }
    with open(SESSION_JSON, "w") as fh:
        json.dump(session_doc, fh, indent=2)
    print(f"wrote {SESSION_JSON}")

    doc = {
        "bench": "dht_hot_path",
        "host": {"backend": jax.default_backend(),
                 "devices": jax.device_count()},
        "per_lookup_us": {k: v * 1e6 for k, v in lk.items()},
        "per_lookup_speedup": {k: lk["eager"] / v for k, v in lk.items()},
        "adaptive_wave": {"n_vals": nv, "n_keys": nk // 4, "waves": waves,
                          "eager_s": te, "deferred_s": td,
                          "deferred_pallas_s": tp},
        "warm_solve_speedup": headline,
        "warm_solve_speedup_pallas": me / mp,
        "engine_solve_s": eng,
        "engine_solve_speedup": eng_speedup,
        "companions": [SESSION_JSON],
    }
    with open(OUT_JSON, "w") as fh:
        json.dump(doc, fh, indent=2)
    print(f"wrote {OUT_JSON}")
    return doc
