"""Pallas TPU kernel: cached gather — the paper's caching optimization as a
VMEM-resident reuse rule.

The caller sorts the key batch (as the DHT router does before bucketing);
inside a block the kernel walks keys sequentially and issues an HBM row DMA
*only when the key differs from the previous one* — adjacent duplicates hit
the in-register "cache", exactly the per-machine memoization of Section 5.3.
The skipped-load count is returned so benchmarks can report cache savings.

The cache carries across block boundaries for *counting* purposes: block
``i > 0`` seeds its previous-key register from the last key of block
``i-1`` (one extra row load), so the total hit count satisfies the exact
identity ``hits == n_valid_keys - n_distinct_valid_keys``.  That identity
is what lets ``ShardedDHT`` derive ``n_unique = valid - hits`` on the
Pallas path bit-identically to the ``dedup_keys`` accounting of the
``jnp.take`` path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dht_gather_kernel(keys_ref, table_ref, o_ref, hits_ref, *, bq: int):
    i = pl.program_id(0)
    D = table_ref.shape[1]
    V = table_ref.shape[0]

    def _load_row(idx):
        # comparisons use the raw key; the load clips into the table so
        # out-of-range keys fetch row V-1 exactly like the take path's clip
        safe = jnp.clip(idx, 0, V - 1)
        return pl.load(table_ref, (pl.ds(safe, 1), slice(None)))

    def step(r, carry):
        prev_key, prev_row, hits = carry
        idx = keys_ref[i * bq + r]
        same = idx == prev_key
        valid = idx >= 0
        row = jax.lax.cond(same, lambda _: prev_row,
                           lambda _: _load_row(idx), None)
        out = jnp.where(valid, row, jnp.zeros_like(row))
        o_ref[r, :] = out[0]
        hits = hits + jnp.where(same & valid, 1, 0)
        return idx, row, hits

    def carry_in(_):
        # seed the cache from the previous block's last key (one extra row
        # load) so cross-block duplicate runs still count as hits and the
        # hits == valid - distinct identity holds over the whole batch
        prev_key = keys_ref[i * bq - 1]
        return prev_key, _load_row(prev_key)

    def fresh(_):
        return jnp.int32(-2), jnp.zeros((1, D), table_ref.dtype)

    prev_key, prev_row = jax.lax.cond(i > 0, carry_in, fresh, None)
    _, _, hits = jax.lax.fori_loop(0, bq, step,
                                   (prev_key, prev_row, jnp.int32(0)))
    hits_ref[0] = hits


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def _dht_gather_pallas(table, sorted_keys, block_q: int, interpret: bool):
    V, D = table.shape
    Q = sorted_keys.shape[0]
    bq = min(block_q, Q)
    assert Q % bq == 0
    kernel = functools.partial(_dht_gather_kernel, bq=bq)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(Q // bq,),
            in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
            out_specs=[
                pl.BlockSpec((bq, D), lambda i, keys: (i, 0)),
                pl.BlockSpec((1,), lambda i, keys: (i,)),
            ],
        ),
        out_shape=[jax.ShapeDtypeStruct((Q, D), table.dtype),
                   jax.ShapeDtypeStruct((Q // bq,), jnp.int32)],
        interpret=interpret,
    )(sorted_keys, table)


def dht_gather_pallas(table, sorted_keys, block_q: int = 64,
                      interpret: bool | None = None):
    """table: (V, D); sorted_keys: (Q,) ascending (-1 pad).
    Returns (out (Q, D), cache_hits (Q//bq,)).

    ``interpret=None`` (the default) resolves by platform: compiled on
    TPU, interpreter everywhere else.  ``interpret`` is static under jit,
    so the detection happens here, outside the traced function.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _dht_gather_pallas(table, sorted_keys, block_q, interpret)
