"""Pallas TPU flash attention BACKWARD + custom_vjp wrapper.

The §Perf analysis (EXPERIMENTS.md, cell 1) shows the dominant residual HBM
traffic of LM training is the attention P-matrix round-trip in the XLA
backward.  This kernel recomputes P per tile in VMEM (never in HBM) and
produces dq, dk, dv.

Decomposition (standard two-pass flash bwd):
  pass 1 (dq): grid (B, H, nq, nk), kv innermost; accumulates
      dq += (P ∘ (dS)) K   with dS = P ∘ (dO·Vᵀ − delta)
  pass 2 (dk/dv): grid (B, Hkv, nk, nq), q innermost; accumulates
      dv += Pᵀ dO (summed over the G query heads of the group),
      dk += dSᵀ Q
  delta = rowsum(dO ∘ O) precomputed in XLA (cheap, O(S·D)).

Validated in interpret mode against jax.grad of the jnp oracle
(tests/test_kernels_bwd.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .kernel import flash_attention_fwd

NEG_INF = -1e30


def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
                   acc_ref, *, scale, causal, window, bq, bk, nk, q_offset):
    ki = pl.program_id(3)
    qi = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq + q_offset
    k_start = ki * bk
    must = True
    if causal:
        must = k_start <= q_start + bq - 1

    @pl.when(must)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        do = do_ref[0, :, 0, :].astype(jnp.float32)
        lse = lse_ref[0, 0, :].astype(jnp.float32)[:, None]
        delta = delta_ref[0, 0, :].astype(jnp.float32)[:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        diff = qpos - kpos
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= diff >= 0
        if window > 0:
            mask &= diff < window
        s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - lse)                       # exact softmax via saved lse
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta) * scale
        acc_ref[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                            preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _fin():
        dq_ref[0, :, 0, :] = acc_ref[...].astype(dq_ref.dtype)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, dk_acc, dv_acc, *, scale, causal, window,
                    bq, bk, nq, G, q_offset):
    qi = pl.program_id(3)   # innermost: q blocks
    ki = pl.program_id(2)

    @pl.when(qi == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_start = qi * bq + q_offset
    k_start = ki * bk
    must = True
    if causal:
        must = k_start <= q_start + bq - 1

    @pl.when(must)
    def _compute():
        k = k_ref[0, :, 0, :].astype(jnp.float32)       # (bk, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        for g in range(G):   # G query heads share this kv head (unrolled)
            q = q_ref[0, :, 0, g, :].astype(jnp.float32)     # (bq, d)
            do = do_ref[0, :, 0, g, :].astype(jnp.float32)
            lse = lse_ref[0, 0, g, :].astype(jnp.float32)[:, None]
            delta = delta_ref[0, 0, g, :].astype(jnp.float32)[:, None]
            s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32) * scale
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            diff = qpos - kpos
            mask = jnp.ones((bq, bk), bool)
            if causal:
                mask &= diff >= 0
            if window > 0:
                mask &= diff < window
            s = jnp.where(mask, s, NEG_INF)
            p = jnp.exp(s - lse)                           # (bq, bk)
            dv_acc[...] += jax.lax.dot_general(
                p, do, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)        # (bk, d)
            dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                     preferred_element_type=jnp.float32)
            ds = p * (dp - delta) * scale
            dk_acc[...] += jax.lax.dot_general(
                ds, q, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)

    @pl.when(qi == nq - 1)
    def _fin():
        dk_ref[0, :, 0, :] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, :, 0, :] = dv_acc[...].astype(dv_ref.dtype)


def _fwd_with_lse(q, k, v, causal, window, block_q, block_kv, interpret):
    """Reference-precision forward that also returns the log-sum-exp rows
    (needed by the bwd kernels). Computed chunk-free in jnp for clarity —
    the fwd Pallas kernel could emit lse as a second output on TPU."""
    B, S, H, D = q.shape
    K, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = 1.0 / np.sqrt(D)
    qf = q.astype(jnp.float32).reshape(B, S, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32)) * scale
    qpos = jnp.arange(S)[:, None] + (K - S)
    kpos = jnp.arange(K)[None, :]
    diff = qpos - kpos
    mask = jnp.ones((S, K), bool)
    if causal:
        mask &= diff >= 0
    if window and window > 0:
        mask &= diff < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    lse = jax.nn.logsumexp(s, axis=-1)                       # (B,Hkv,G,S)
    p = jnp.exp(s - lse[..., None])
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, D).astype(q.dtype), lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention_trainable(q, k, v, causal=True, window=0, block_q=128,
                              block_kv=128, interpret=True):
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_kv=block_kv,
                               interpret=interpret)


def _vjp_fwd(q, k, v, causal, window, block_q, block_kv, interpret):
    o, lse = _fwd_with_lse(q, k, v, causal, window, block_q, block_kv,
                           interpret)
    return o, (q, k, v, o, lse)


def _vjp_bwd(causal, window, block_q, block_kv, interpret, res, do):
    q, k, v, o, lse = res
    B, S, H, D = q.shape
    K, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    bq = min(block_q, S)
    bk = min(block_kv, K)
    nq, nk = S // bq, K // bk
    scale = 1.0 / np.sqrt(D)
    q_offset = K - S
    delta = (do.astype(jnp.float32) * o.astype(jnp.float32)).sum(-1)  # (B,S,H)
    delta_h = delta.reshape(B, S, Hkv, G).transpose(0, 2, 3, 1)       # B,Hkv,G,S
    lse_h = lse                                                        # B,Hkv,G,S

    # --- dq
    lse_q = lse_h.transpose(0, 3, 1, 2).reshape(B, S, H)   # (B,S,H) per q head
    delta_q = delta
    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk, nk=nk,
                          q_offset=q_offset),
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda b, h, qi, ki, G=G: (b, ki, h // G, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda b, h, qi, ki, G=G: (b, ki, h // G, 0)),
            pl.BlockSpec((1, bq, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, qi, ki: (b, h, qi)),
            pl.BlockSpec((1, 1, bq), lambda b, h, qi, ki: (b, h, qi)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse_q.transpose(0, 2, 1), delta_q.transpose(0, 2, 1))

    # --- dk, dv (grouped per kv head)
    q_g = q.reshape(B, S, Hkv, G, D)
    do_g = do.reshape(B, S, Hkv, G, D)
    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk, nq=nq, G=G,
                          q_offset=q_offset),
        grid=(B, Hkv, nk, nq),
        in_specs=[
            pl.BlockSpec((1, bq, 1, G, D),
                         lambda b, h, ki, qi: (b, qi, h, 0, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, ki, qi: (b, ki, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, ki, qi: (b, ki, h, 0)),
            pl.BlockSpec((1, bq, 1, G, D),
                         lambda b, h, ki, qi: (b, qi, h, 0, 0)),
            pl.BlockSpec((1, 1, G, bq), lambda b, h, ki, qi: (b, h, 0, qi)),
            pl.BlockSpec((1, 1, G, bq), lambda b, h, ki, qi: (b, h, 0, qi)),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, 1, D), lambda b, h, ki, qi: (b, ki, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, ki, qi: (b, ki, h, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((B, K, Hkv, D), k.dtype),
                   jax.ShapeDtypeStruct((B, K, Hkv, D), v.dtype)],
        scratch_shapes=[pltpu.VMEM((bk, D), jnp.float32),
                        pltpu.VMEM((bk, D), jnp.float32)],
        interpret=interpret,
    )(q_g, k, v, do_g, lse_h, delta_h)
    return dq, dk, dv


flash_attention_trainable.defvjp(_vjp_fwd, _vjp_bwd)
