#!/usr/bin/env bash
# Repo health check: lint (when ruff is available) + the tier-1 test suite.
#
#   scripts/check.sh            # lint + full tier-1 pytest
#   scripts/check.sh --fast     # lint + the observability/docs/engine subset
#
# ruff is optional (the dev container does not ship it); when absent the
# lint step is skipped with a notice instead of failing the check.
set -euo pipefail
cd "$(dirname "$0")/.."

if command -v ruff >/dev/null 2>&1; then
    echo "== ruff check"
    ruff check src/repro benchmarks tests
else
    echo "== ruff not installed; skipping lint"
fi

echo "== tier-1 pytest"
export PYTHONPATH=src
if [[ "${1:-}" == "--fast" ]]; then
    exec python -m pytest -x -q tests/test_obs.py tests/test_docs.py \
        tests/test_engine.py tests/test_smoke_benchmarks.py
fi
exec python -m pytest -x -q
