"""input_specs + lowerable step construction for every (arch x shape) cell.

Everything here is ShapeDtypeStruct-based: no device allocation.  Each cell
resolves to a ``Lowerable``: a jittable function, ShapeDtypeStruct args,
in/out shardings, and metadata (MODEL_FLOPS etc. for the roofline).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.registry import ArchEntry, get
from ..configs.shapes import ShapeSpec, sampled_block_sizes
from ..models import transformer as tr
from ..models.gnn.common import GraphBatch
from ..optim import adamw
from . import steps
from .mesh import data_axes, n_chips
from .sharding import (batch_sharding, flat_shard, kv_cache_shardings,
                       lm_param_shardings, rec_param_shardings, replicated)

S = jax.ShapeDtypeStruct


@dataclasses.dataclass
class Lowerable:
    arch_id: str
    shape_name: str
    fn: Callable
    args: Tuple
    in_shardings: Any
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    model_flops: float          # 6·N·D train / 2·N·D inference (active params)
    notes: str = ""

    def lower(self, mesh):
        jitted = jax.jit(self.fn, in_shardings=self.in_shardings,
                         out_shardings=self.out_shardings,
                         donate_argnums=self.donate_argnums)
        return jitted.lower(*self.args)


def _pad_to(x: int, mult: int) -> int:
    return int(int(np.ceil(x / mult)) * mult)


def _opt_cfg() -> adamw.AdamWConfig:
    return adamw.AdamWConfig()


# --------------------------------------------------------------------------
# LM cells
# --------------------------------------------------------------------------
def _lm_lowerable(entry: ArchEntry, shape: ShapeSpec, mesh,
                  overrides=None) -> Lowerable:
    cfg: tr.TransformerConfig = entry.config
    dpn = int(np.prod([mesh.shape[a] for a in data_axes(mesh)]))
    B, SL = shape.global_batch, shape.seq_len
    sctx = tr.ShardCtx(mesh, data_axes(mesh))
    if shape.kind == "train":
        cfg = dataclasses.replace(cfg, remat="dots")
    opt_overrides = {}
    if overrides:
        overrides = dict(overrides)
        for k in list(overrides):
            if k.startswith("opt_"):
                opt_overrides[k[4:]] = overrides.pop(k)
        cfg = dataclasses.replace(cfg, **overrides)
    params_shape = jax.eval_shape(
        functools.partial(tr.init_params, cfg, dtype=jnp.bfloat16),
        jax.random.PRNGKey(0))
    p_sh = lm_param_shardings(mesh, params_shape)
    n_active = cfg.active_param_count()

    if shape.kind == "train":
        opt_cfg = dataclasses.replace(_opt_cfg(), **opt_overrides)
        opt_shape = jax.eval_shape(
            functools.partial(adamw.init_state, cfg=opt_cfg), params_shape)
        o_sh = {"m": lm_param_shardings(mesh, opt_shape["m"]),
                "v": lm_param_shardings(mesh, opt_shape["v"]),
                "step": NamedSharding(mesh, P())}
        tok = S((B, SL), jnp.int32)
        b_sh = batch_sharding(mesh, 2)
        fn = functools.partial(steps.lm_train_step, cfg, opt_cfg,
                               sctx=sctx)
        metrics_sh = {k: NamedSharding(mesh, P()) for k in
                      ["loss", "nll", "aux", "lr", "grad_norm"]}
        return Lowerable(
            entry.arch_id, shape.name, fn,
            (params_shape, opt_shape, tok, tok),
            (p_sh, o_sh, b_sh, b_sh), (p_sh, o_sh, metrics_sh), (0, 1),
            model_flops=6.0 * n_active * B * SL)

    if shape.kind == "prefill":
        tok = S((B, SL), jnp.int32)
        b_sh = batch_sharding(mesh, 2)
        fn = functools.partial(steps.lm_prefill_step, cfg, sctx=sctx)
        return Lowerable(
            entry.arch_id, shape.name, fn, (params_shape, tok),
            (p_sh, b_sh), None, (),
            model_flops=2.0 * n_active * B * SL)

    # decode
    cache_shape = steps.lm_cache_shape(cfg, B, SL)
    cache = {"k": S(cache_shape, jnp.bfloat16),
             "v": S(cache_shape, jnp.bfloat16),
             "length": S((B,), jnp.int32)}
    c_sh = {"k": kv_cache_shardings(mesh, cache_shape, B),
            "v": kv_cache_shardings(mesh, cache_shape, B),
            "length": NamedSharding(mesh, P())}
    tok = S((B,), jnp.int32)
    t_sh = (batch_sharding(mesh, 1) if B % dpn == 0 and B >= dpn
            else NamedSharding(mesh, P()))
    fn = functools.partial(steps.lm_decode_step, cfg, sctx=sctx)
    return Lowerable(
        entry.arch_id, shape.name, fn, (params_shape, cache, tok),
        (p_sh, c_sh, t_sh), None, (1,),
        model_flops=2.0 * n_active * B,
        notes=f"cache_len={cache_shape[2]}")


# --------------------------------------------------------------------------
# GNN cells
# --------------------------------------------------------------------------
def _gnn_batch_struct(entry: ArchEntry, shape: ShapeSpec, mesh
                      ) -> Tuple[GraphBatch, GraphBatch]:
    """Returns (batch of ShapeDtypeStructs, batch of shardings)."""
    chips = n_chips(mesh)
    if shape.kind == "gnn_sampled":
        n_nodes, n_edges_dir = sampled_block_sizes(shape)
        n_graphs = 1
        d_feat = shape.d_feat
    elif shape.kind == "gnn_batched":
        n_nodes = shape.n_nodes * shape.n_graphs
        n_edges_dir = 2 * shape.n_edges * shape.n_graphs
        n_graphs = shape.n_graphs
        d_feat = 64
    else:
        n_nodes = shape.n_nodes
        n_edges_dir = 2 * shape.n_edges
        n_graphs = 1
        d_feat = shape.d_feat
    N = _pad_to(n_nodes, chips)
    E = _pad_to(n_edges_dir, chips)
    arch = entry.arch_id
    fs = functools.partial(flat_shard, mesh)
    rep = NamedSharding(mesh, P())
    node_feat = positions = species = None
    nf_sh = pos_sh = sp_sh = None
    if arch in ("gcn-cora", "gin-tu"):
        df = d_feat   # the cell's dataset feature width drives the input dim
        node_feat = S((N, df), jnp.float32); nf_sh = fs(2)
    else:  # schnet / mace consume positions + species
        positions = S((N, 3), jnp.float32); pos_sh = fs(2)
        species = S((N,), jnp.int32); sp_sh = fs(1)
    if arch == "gcn-cora":       # node classification
        labels, lab_sh = S((N,), jnp.int32), fs(1)
    elif arch == "gin-tu":       # graph classification
        labels, lab_sh = S((n_graphs,), jnp.int32), rep
    else:                        # energies per graph
        labels, lab_sh = S((n_graphs,), jnp.float32), rep
    batch = GraphBatch(
        senders=S((E,), jnp.int32), receivers=S((E,), jnp.int32),
        node_mask=S((N,), jnp.bool_), edge_mask=S((E,), jnp.bool_),
        graph_ids=S((N,), jnp.int32), n_graphs=n_graphs,
        node_feat=node_feat, positions=positions, species=species,
        labels=labels)
    shard = GraphBatch(
        senders=fs(1), receivers=fs(1), node_mask=fs(1), edge_mask=fs(1),
        graph_ids=fs(1), n_graphs=n_graphs, node_feat=nf_sh,
        positions=pos_sh, species=sp_sh, labels=lab_sh)
    return batch, shard


def _gnn_flops(entry: ArchEntry, cfg, batch: GraphBatch) -> float:
    """Analytic useful-FLOPs estimate, per family (fwd+bwd ~ 3x fwd):
    GCN/GIN: per-edge add (2d) + per-node dense transform;
    SchNet:  per-edge filter MLP + cfconv; MACE: per-edge radial MLPs +
    moment accumulation over 13 tensor components."""
    E = batch.senders.shape[0]
    N = batch.node_mask.shape[0]
    arch = entry.arch_id
    if arch == "gcn-cora":
        d_in, d = cfg.d_feat, cfg.d_hidden
        fwd = E * 2 * (d + cfg.n_classes) + N * 2 * (d_in * d + d * cfg.n_classes)
    elif arch == "gin-tu":
        d_in, d = cfg.d_feat, cfg.d_hidden
        fwd = cfg.n_layers * (E * 2 * d + N * 4 * d * d) + N * 2 * d_in * d
    elif arch == "schnet":
        d, r = cfg.d_hidden, cfg.n_rbf
        fwd = cfg.n_interactions * (E * 2 * (r * d + d * d + d)
                                    + N * 4 * d * d)
    else:  # mace
        d, r = cfg.d_hidden, cfg.n_rbf
        per_edge = 3 * 2 * (r * d + d * d) + 2 * d * 13   # radial MLPs + moments
        per_node = 6 * d * d + 6 * 2 * d * 13             # updates + B-features
        fwd = cfg.n_layers * (E * per_edge + N * per_node)
    return 3.0 * fwd


def _gnn_lowerable(entry: ArchEntry, shape: ShapeSpec, mesh) -> Lowerable:
    cfg = entry.config
    if entry.arch_id in ("gcn-cora", "gin-tu"):
        # input layer width follows the cell's dataset
        df = (shape.d_feat if shape.kind in ("gnn_full", "gnn_sampled")
              else 64)
        cfg = dataclasses.replace(cfg, d_feat=df)
    params_shape = jax.eval_shape(
        functools.partial(steps.GNN_MODULES[entry.arch_id].init_params, cfg),
        jax.random.PRNGKey(0))
    p_sh = replicated(mesh, params_shape)
    opt_shape = jax.eval_shape(adamw.init_state, params_shape)
    o_sh = replicated(mesh, opt_shape)
    batch, b_sh = _gnn_batch_struct(entry, shape, mesh)
    fn = functools.partial(steps.gnn_train_step, entry.arch_id, cfg, _opt_cfg())
    metric_keys = {"gcn-cora": ["loss", "nll"], "gin-tu": ["loss", "nll"],
                   "schnet": ["loss", "mse"], "mace": ["loss", "mse"]}
    m_sh = {k: NamedSharding(mesh, P()) for k in
            metric_keys[entry.arch_id] + ["lr", "grad_norm"]}
    return Lowerable(
        entry.arch_id, shape.name, fn,
        (params_shape, opt_shape, batch),
        (p_sh, o_sh, b_sh), (p_sh, o_sh, m_sh), (0, 1),
        model_flops=_gnn_flops(entry, cfg, batch))


# --------------------------------------------------------------------------
# RecSys cells
# --------------------------------------------------------------------------
def _rec_lowerable(entry: ArchEntry, shape: ShapeSpec, mesh) -> Lowerable:
    cfg = entry.config
    params_shape = jax.eval_shape(
        functools.partial(__import__("repro.models.sasrec",
                                     fromlist=["init_params"]).init_params,
                          cfg), jax.random.PRNGKey(0))
    p_sh = rec_param_shardings(mesh, params_shape)
    B = shape.global_batch
    dpn = int(np.prod([mesh.shape[a] for a in data_axes(mesh)]))
    seq = S((B, cfg.seq_len), jnp.int32)
    b2 = (batch_sharding(mesh, 2) if B % dpn == 0 and B >= dpn
          else NamedSharding(mesh, P()))
    d_model_flops = 2.0 * cfg.embed_dim * cfg.embed_dim * 10  # per token blocks
    if shape.kind == "rec_train":
        opt_shape = jax.eval_shape(adamw.init_state, params_shape)
        o_sh = {"m": rec_param_shardings(mesh, opt_shape["m"]),
                "v": rec_param_shardings(mesh, opt_shape["v"]),
                "step": NamedSharding(mesh, P())}
        fn = functools.partial(steps.rec_train_step, cfg, _opt_cfg())
        m_sh = {k: NamedSharding(mesh, P()) for k in ["loss", "bpr", "lr",
                                                      "grad_norm"]}
        return Lowerable(entry.arch_id, shape.name, fn,
                         (params_shape, opt_shape, seq, seq, seq),
                         (p_sh, o_sh, b2, b2, b2), (p_sh, o_sh, m_sh), (0, 1),
                         model_flops=3 * B * cfg.seq_len * d_model_flops)
    if shape.kind == "rec_serve":
        n_cand = 1024
        cand = S((B, n_cand), jnp.int32)
        fn = functools.partial(steps.rec_serve_step, cfg)
        return Lowerable(entry.arch_id, shape.name, fn,
                         (params_shape, seq, cand), (p_sh, b2, b2), None, (),
                         model_flops=B * (cfg.seq_len * d_model_flops
                                          + 2 * n_cand * cfg.embed_dim))
    # retrieval: 1 user against the full table
    fn = functools.partial(steps.rec_retrieval_step, cfg)
    return Lowerable(entry.arch_id, shape.name, fn,
                     (params_shape, seq), (p_sh, NamedSharding(mesh, P())),
                     None, (),
                     model_flops=B * (cfg.seq_len * d_model_flops
                                      + 2 * cfg.n_items * cfg.embed_dim))


# --------------------------------------------------------------------------
def build_lowerable(arch_id: str, shape_name: str, mesh,
                    overrides=None) -> Lowerable:
    entry = get(arch_id)
    shape = entry.shapes[shape_name]
    if entry.family == "lm":
        return _lm_lowerable(entry, shape, mesh, overrides=overrides)
    if entry.family == "gnn":
        return _gnn_lowerable(entry, shape, mesh)
    return _rec_lowerable(entry, shape, mesh)


def input_specs(arch_id: str, shape_name: str, mesh):
    """ShapeDtypeStruct stand-ins for every model input of a cell (the
    pattern named in the brief): returns the args tuple the dry-run lowers
    with — weak-type-correct, shardable, no device allocation."""
    return build_lowerable(arch_id, shape_name, mesh).args
