"""Maximal independent set (paper Proposition 4.2 / Section 5.3 case study).

Both implementations compute the *lexicographically-first MIS* over a random
vertex permutation π — identical output to the sequential greedy (oracle).

``mis_ampc``  — the AMPC algorithm of Figure 1: one shuffle builds the
  rank-directed graph and writes it to the DHT; one launch then resolves every
  vertex by adaptive queries against that immutable snapshot.  The per-machine
  recursion of Yoshida et al. becomes an in-round dependency-fixpoint: a
  vertex joins when all lower-rank neighbours are OUT; a vertex is OUT when a
  neighbour is IN.  Fischer–Noever gives O(log n) fixpoint iterations w.h.p.;
  all iterations read the same snapshot, so this is 2 AMPC rounds total.
  Query/byte counters reproduce the paper's Fig 3/4/9 measurements, including
  the caching (dedup) savings.

``mis_mpc_rootset`` — the MPC baseline of Figure 2: the same rule, but each
  phase is a materialized launch with 2 shuffles (join + removal), O(log n)
  phases.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.coo import UGraph
from .rounds import RoundLedger

UNKNOWN, IN, OUT = 0, 1, 2


@functools.partial(jax.jit, static_argnames=("n",))
def _mis_fixpoint_masked(senders, receivers, rank, n: int, edge_ok):
    """LFMIS fixpoint with an edge-validity mask (the batched-solve core).

    ``edge_ok`` marks the real directed edges; masked lanes (the padding a
    ``solve_many`` shape bucket introduces) never contribute to blocking,
    joining, or query counts, so each batch lane reproduces exactly the
    trajectory of the unpadded sequential fixpoint.  Padding vertices have
    no valid edges and resolve to IN on the first wave.

    Returns (status(n,), iters, queries_nodedup, queries_dedup).
    Query accounting per wave: every undecided vertex fetches the status of
    each of its neighbours (no-dedup count); with caching each *distinct*
    neighbour is fetched once per machine — we model the per-wave dedup as
    one fetch per distinct queried vertex (paper Section 5.3).
    """
    status0 = jnp.zeros((n,), jnp.int32)

    def cond(s):
        status, it, q0, q1 = s
        return jnp.any(status == UNKNOWN)

    def body(s):
        status, it, q0, q1 = s
        s_unk = (status[senders] == UNKNOWN) & edge_ok
        lower = rank[receivers] < rank[senders]
        # does sender have any lower-rank neighbour that is not OUT?
        blocked = s_unk & lower & (status[receivers] != OUT)
        has_block = jax.ops.segment_max(blocked.astype(jnp.int32), senders,
                                        num_segments=n)
        nbr_in = s_unk & (status[receivers] == IN)
        has_in = jax.ops.segment_max(nbr_in.astype(jnp.int32), senders,
                                     num_segments=n)
        unk = status == UNKNOWN
        status = jnp.where(unk & (has_in > 0), OUT, status)
        status = jnp.where(unk & (has_in <= 0) & (has_block <= 0), IN, status)
        # queries: edges scanned this wave (sender undecided)
        scanned = s_unk.sum()
        # dedup: distinct receivers queried this wave
        probe = jnp.zeros((n,), jnp.int32).at[
            jnp.where(s_unk, receivers, n)].set(1, mode="drop")
        distinct = probe.sum()
        # gate the wave counter on this lane actually having work: under a
        # vmapped while_loop a finished batch lane may still execute the
        # body, and the query counters are already zero then (s_unk empty)
        live = unk.any().astype(jnp.int32)
        return status, it + live, q0 + scanned, q1 + distinct

    status, iters, q0, q1 = jax.lax.while_loop(
        cond, body, (status0, jnp.int32(0), jnp.int32(0), jnp.int32(0)))
    return status, iters, q0, q1


def _mis_fixpoint(senders, receivers, rank, n: int):
    """Run the LFMIS fixpoint to completion inside one program.

    The unmasked (single-graph) entry point: every edge lane is valid.
    Returns (status(n,), iters, queries_nodedup, queries_dedup); see
    :func:`_mis_fixpoint_masked` for the query-accounting model.
    """
    return _mis_fixpoint_masked(senders, receivers, rank, n,
                                jnp.ones(senders.shape, bool))


# --------------------------------------------------------------------------
# Deprecated shims — the drivers moved to repro.ampc.solvers; prefer
# AmpcEngine().solve(g, "mis") / .solve(g, "mis-mpc").
# --------------------------------------------------------------------------
def mis_ampc(g: UGraph, seed: int = 0,
             ledger: Optional[RoundLedger] = None,
             caching: bool = True) -> Tuple[np.ndarray, dict]:
    """Deprecated shim over repro.ampc.solvers.mis_ampc."""
    from ..ampc import solvers
    from ..ampc.deprecation import warn_once
    warn_once("repro.core.mis.mis_ampc", 'AmpcEngine().solve(g, "mis")')
    return solvers.mis_ampc(g, seed=seed, ledger=ledger, caching=caching)


def mis_mpc_rootset(g: UGraph, seed: int = 0,
                    ledger: Optional[RoundLedger] = None,
                    max_phases: int = 500) -> Tuple[np.ndarray, dict]:
    """Deprecated shim over repro.ampc.solvers.mis_mpc_rootset."""
    from ..ampc import solvers
    from ..ampc.deprecation import warn_once
    warn_once("repro.core.mis.mis_mpc_rootset",
              'AmpcEngine().solve(g, "mis-mpc")')
    return solvers.mis_mpc_rootset(g, seed=seed, ledger=ledger,
                                   max_phases=max_phases)
