"""Deliverable (g): roofline table from the dry-run artifacts.

Reads results/dryrun.jsonl (written by repro.launch.dryrun) and emits the
per-(arch x shape x mesh) table with the three roofline terms, dominant
bottleneck, useful-FLOPs ratio, and the one-line mitigation note.
"""
from __future__ import annotations

import json
import os

from .common import fmt_table
from .registry import bench

MITIGATIONS = {
    ("lm", "memory"): "bigger attn chunks / bf16 accum / flash bwd kernel",
    ("lm", "collective"): "EP all_to_all for MoE; 2D attn sharding; "
                          "reduce-scatter grads",
    ("lm", "compute"): "near roofline - tune MXU tile shapes",
    ("gnn", "memory"): "fuse gather+segment_sum (segment_matmul kernel)",
    ("gnn", "collective"): "partition-aware edge placement (minimize cut)",
    ("rec", "memory"): "dedup-gather (dht_gather kernel) on hot rows",
    ("rec", "collective"): "replicate hot embedding rows; batch all_to_all",
}

FAMILY = {"gemma3-12b": "lm", "qwen2.5-32b": "lm", "qwen3-4b": "lm",
          "llama4-scout-17b-a16e": "lm", "mixtral-8x22b": "lm",
          "mace": "gnn", "gin-tu": "gnn", "schnet": "gnn", "gcn-cora": "gnn",
          "sasrec": "rec"}


def load(paths=("results/dryrun.jsonl", "results/dryrun_fix.jsonl")):
    recs = {}
    for p in paths:
        if not os.path.exists(p):
            continue
        for line in open(p):
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r["mesh"])] = r  # later files win
    return list(recs.values())


@bench("roofline", summary="Roofline table from dry-run artifacts")
def run(paths=("results/dryrun.jsonl", "results/dryrun_fix.jsonl"),
        mesh_filter=None):
    recs = load(paths)
    rows = []
    for r in sorted(recs, key=lambda r: (FAMILY.get(r["arch"], "z"),
                                         r["arch"], r["shape"], r["mesh"])):
        if mesh_filter and r["mesh"] != mesh_filter:
            continue
        if r["status"] == "skipped":
            rows.append([r["arch"], r["shape"], r["mesh"], "SKIP", "-", "-",
                         "-", "-", "-", r["reason"][:46]])
            continue
        if r["status"] != "ok":
            rows.append([r["arch"], r["shape"], r["mesh"], "ERROR", "-", "-",
                         "-", "-", "-", r["error"][:46]])
            continue
        t = r["roofline"]
        fam = FAMILY.get(r["arch"], "lm")
        rows.append([
            r["arch"], r["shape"], r["mesh"],
            f"{t['t_compute_s']:.3f}", f"{t['t_memory_s']:.3f}",
            f"{t['t_collective_s']:.3f}", t["dominant"],
            f"{t['useful_flops_fraction']:.3f}",
            f"{t['roofline_fraction']:.4f}",
            MITIGATIONS.get((fam, t["dominant"]), "")[:46],
        ])
    out = fmt_table(["arch", "shape", "mesh", "t_comp", "t_mem", "t_coll",
                     "dominant", "useful", "roofline", "mitigation"], rows)
    print(out)
    return {"rows": rows, "markdown": out}


if __name__ == "__main__":
    run()
