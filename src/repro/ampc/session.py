"""``GraphSession`` — DHT snapshot reuse across solves on one graph.

The first shuffle of every fixpoint-style AMPC solve is the same work: write
the graph's KV representation into the DHT snapshot (the rank-directed
symmetric adjacency for MIS, the edge list for the matching family).  A
serving workload that answers several queries on one graph — the paper's
"MIS then matching on one snapshot" pattern — repeats that write per solve
even though the snapshot is immutable within a session.

``engine.session(graph)`` returns a :class:`GraphSession` that materializes
the graph KV snapshot **once**, on the first solve that needs it, and lets
every later solve on the same graph hit it:

    with AmpcEngine(seed=0) as eng:
        sess = eng.session(g)
        mis = sess.solve("mis")             # cold: writes the snapshot
        mm = sess.solve("matching")         # warm: skips the WriteKV shuffle
        vc = sess.solve("vertex-cover")     # warm
        mm.stats["snapshot"]                # {"hit": True, ...}

Accounting follows the :class:`~repro.ampc.cache.SolverCache` model the
compiled-solver cache already uses: the snapshot store *is* a
``SolverCache`` (1 miss for the build, 1 hit per solve that reuses it),
surfaced engine-wide through ``engine.cache_info(kind="snapshot")`` and
per-solve through ``AmpcResult.stats["snapshot"]``.  A warm solve records
one fewer materialized round in its ledger (the WriteKV shuffle is the one
it skipped), which is exactly the paper's claim for snapshot reuse: the
adaptive in-round queries repeat, the shuffle does not.

Invalidation: ``session.invalidate()`` (or mutating the graph and opening a
new session) evicts the session's entries from the snapshot cache; the next
solve rebuilds.  Sessions are keyed by identity, not content — two sessions
on equal graphs build two snapshots, because the engine cannot know the
caller keeps the arrays immutable.

The snapshot is a *view-keyed* KV layout: alongside the flat graph-KV
image (``graph_kv``: symmetric adjacency + edge list, shared by ``mis``,
``matching``, ``weighted-matching``, and ``vertex-cover``) it lazily
carries the richer per-problem structures — the ternarized Δ<=3 adjacency
with ``msf``'s weight-sorted edge structure (``tern_msf``), the
unit-weight ternarization + first-slot map ``connectivity`` contracts
through (``tern_cc``), the dense-path edge/weight image (``dense_msf``),
and the cycle adjacency for ``one-vs-two`` (``cycle_adj``).
Each view is built once, under its own shuffle on the first solve that
needs it, and cached at ``(session_key, view)``; ``invalidate()`` evicts
every view of the session by key prefix.  Warm ``msf`` / ``connectivity``
solves therefore skip both the WriteGraphKV-style shuffle *and* the
per-solve ternarize rebuild: 1 materialized round instead of 2.

Problems outside :data:`SNAPSHOT_PROBLEMS` — the MPC baselines and the
multi-launch variants (``msf-mpc``, ``matching-levels``, ``msf-kkt``, …,
whose shuffle structure is per-phase, not a reusable KV image) — run
unchanged through a session; their stats report
``{"hit": False, "supported": False}``.  Alias names resolve through the
registry first, so ``"cc"`` is snapshot-aware while ``"connectivity-mpc"``
is not.

Session solves inherit the engine's deferred accounting: warm solves stay
host-sync free until the single per-solve ledger harvest (see
``RoundLedger.harvest`` and the "Accounting model" section of
docs/architecture.md), so snapshot reuse composes with the one-transfer
hot path rather than re-introducing per-lookup syncs.
"""
from __future__ import annotations

import itertools
import threading
from typing import Optional, TYPE_CHECKING

import jax.numpy as jnp
import numpy as np

from ..core.one_vs_two import cycle_adjacency
from ..core.rounds import nbytes_of
from ..core.ternarize import ternarize
from ..graph.coo import UGraph
from .cache import SolverCache

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from .engine import AmpcEngine

__all__ = ["GraphSession", "GraphSnapshot", "SNAPSHOT_PROBLEMS"]

# problems whose first shuffle writes a reusable KV view of the graph
# (flat graph-KV image, ternarized adjacency, or cycle adjacency)
SNAPSHOT_PROBLEMS = frozenset(
    {"mis", "matching", "weighted-matching", "vertex-cover",
     "msf", "connectivity", "one-vs-two"})

_session_ids = itertools.count(1)


class GraphSnapshot:
    """Lazy, cached device-side KV image of one graph.

    ``materialize(ledger)`` returns ``(entries, hit)``: the dict of device
    arrays every snapshot-aware solver reads (``sym_senders`` /
    ``sym_receivers`` for vertex fixpoints, ``edge_u`` / ``edge_v`` for
    edge fixpoints), and whether the image was already in the cache.  The
    cold build runs under a ``WriteGraphKV`` shuffle on the *calling
    solve's* ledger — the build cost is attributed to the solve that paid
    it, and warm solves record no shuffle at all.
    """

    def __init__(self, graph, key, cache: SolverCache):
        self.graph = graph
        self.key = key
        self._cache = cache

    def materialize(self, ledger):
        g = self.graph

        def build():
            # one write covers both the directed-adjacency and the
            # edge-list views: a single snapshot serves MIS and the
            # matching family alike
            with ledger.shuffle("WriteGraphKV", nbytes_of(g.edges) * 3):
                s, r, _, _ = g.symmetric()
                return {
                    "sym_senders": jnp.asarray(s),
                    "sym_receivers": jnp.asarray(r),
                    "edge_u": jnp.asarray(g.edges[:, 0]),
                    "edge_v": jnp.asarray(g.edges[:, 1]),
                }

        entries, hit = self._cache.get_or_build((self.key, "graph_kv"), build)
        return entries, hit

    # ------------------------------------------------------------------
    def _view(self, view: str, shuffle_name: str, nbytes: int, builder,
              ledger):
        """Build-or-hit one named KV view at ``(session_key, view)``.

        The cold build runs under ``shuffle_name`` on the calling solve's
        ledger, mirroring ``materialize``: cost lands on the solve that
        paid it, warm solves record no shuffle for the view at all.
        """
        def build():
            with ledger.shuffle(shuffle_name, nbytes):
                return builder()

        return self._cache.get_or_build((self.key, view), build)

    def materialize_tern(self, ledger, unit: bool = False):
        """Ternarized Δ<=3 adjacency view (``tern_msf`` / ``tern_cc``).

        ``unit=True`` is connectivity's variant: weights are replaced by
        the edge ids (any distinct weights do), and the view also carries
        ``first_slot`` — the first tern slot of each original vertex,
        through which component labels are read back.
        """
        g = self.graph

        def build():
            gw = (UGraph(g.n, g.edges, np.arange(g.m, dtype=np.float32))
                  if unit else g)
            tg = ternarize(gw)
            bn, bw, be = tg.g.padded_adj(3)
            entries = {
                "tg": tg,
                "nbr": jnp.asarray(bn),
                "nbw": jnp.asarray(bw),
                "nbe": jnp.asarray(be),
                "tu": jnp.asarray(tg.g.edges[:, 0]),
                "tv": jnp.asarray(tg.g.edges[:, 1]),
                "tw": jnp.asarray(tg.g.weights),
                "teid": jnp.asarray(tg.orig_eid),
            }
            if unit:
                entries["first_slot"] = jnp.asarray(
                    np.searchsorted(tg.node_of, np.arange(g.n)), jnp.int32)
            return entries

        nbytes = (nbytes_of(g.edges) if unit
                  else nbytes_of(g.edges, g.weights))
        return self._view("tern_cc" if unit else "tern_msf",
                          "WriteTernKV", nbytes, build, ledger)

    def materialize_dense(self, ledger):
        """Dense-path MSF view (``dense_msf``): edge/weight device image."""
        g = self.graph

        def build():
            return {
                "edge_u": jnp.asarray(g.edges[:, 0]),
                "edge_v": jnp.asarray(g.edges[:, 1]),
                "edge_w": jnp.asarray(g.weights),
            }

        return self._view("dense_msf", "WriteGraphKV",
                          nbytes_of(g.edges, g.weights), build, ledger)

    def materialize_cycle(self, ledger):
        """Cycle adjacency view (``cycle_adj``) for one-vs-two."""
        g = self.graph

        def build():
            return {"cycle_nbr": jnp.asarray(cycle_adjacency(g))}

        return self._view("cycle_adj", "WriteKV",
                          nbytes_of(g.edges), build, ledger)

    def stat(self, hit: bool) -> dict:
        """The ``AmpcResult.stats["snapshot"]`` payload for one solve."""
        return {"hit": bool(hit), "key": self.key, "supported": True}


class GraphSession:
    """Multi-solve handle on one graph; see the module docstring.

    Thin by design: every solve still goes through ``engine.solve`` /
    ``engine.submit`` (same ledgers, spans, metrics, retries) — the session
    only threads the shared :class:`GraphSnapshot` into the solver and
    annotates the result stats.
    """

    def __init__(self, engine: "AmpcEngine", graph):
        self.engine = engine
        self.graph = graph
        self.key = ("snapshot", next(_session_ids))
        self.snapshot = GraphSnapshot(graph, self.key,
                                      engine._snapshot_cache)
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    def _supported(self, problem: str) -> bool:
        from . import registry
        return registry.get(problem).name in SNAPSHOT_PROBLEMS

    def solve(self, problem: str, **opts):
        """``engine.solve(self.graph, problem)`` through the snapshot."""
        if self._supported(problem):
            res = self.engine.solve(self.graph, problem,
                                    snapshot=self.snapshot, **opts)
        else:
            res = self.engine.solve(self.graph, problem, **opts)
            res.stats.setdefault("snapshot",
                                 {"hit": False, "supported": False})
        return res

    def submit(self, problem: str, **opts):
        """Async variant: ``engine.submit`` with the session snapshot."""
        if self._supported(problem):
            return self.engine.submit(self.graph, problem,
                                      snapshot=self.snapshot, **opts)
        return self.engine.submit(self.graph, problem, **opts)

    # ------------------------------------------------------------------
    def invalidate(self) -> int:
        """Evict this session's snapshot; the next solve rebuilds.

        Call after mutating the graph's arrays in place.  Returns the
        number of cache entries dropped (0 if never materialized).
        """
        return self.engine._snapshot_cache.evict(self.key)

    def __repr__(self):
        return (f"GraphSession(key={self.key!r}, n={self.graph.n}, "
                f"m={self.graph.m})")
