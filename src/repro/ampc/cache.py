"""Engine-level compiled-solver cache.

Tracing and compiling a batched fixpoint is the dominant fixed cost of a
``solve_many`` bucket launch (the numerical work on bucket-sized graphs is
often milliseconds; XLA compilation is seconds).  ``SolverCache`` memoizes
the traced solver callable per ``(problem, backend, bucket)`` key so
repeated traffic on same-sized graphs skips tracing entirely.

Accounting model: one *miss* per solver actually built; one *hit* per graph
that reuses an already-built solver.  A bucket launch over ``B`` graphs on a
cold key therefore records 1 miss + ``B - 1`` hits (the compile is amortized
across the other occupants); on a warm key it records ``B`` hits.  The
counters surface per solve on ``AmpcResult.stats["solver_cache"]`` and
engine-wide through ``AmpcEngine.cache_info()``.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, Hashable, Tuple


@dataclasses.dataclass(frozen=True)
class CacheInfo:
    """Snapshot of cache effectiveness (mirrors ``functools.lru_cache``)."""

    hits: int
    misses: int
    size: int

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class SolverCache:
    """Thread-safe memo of compiled batched solvers keyed by bucket.

    Keys are arbitrary hashables; the engine uses
    ``(problem, backend_name, n_bucket, m_bucket, extra...)`` where
    ``extra`` captures any option that changes the traced program (e.g. the
    static walk budget of one-vs-two).
    """

    def __init__(self, metrics=None):
        self._store: Dict[Hashable, Any] = {}
        self._hits = 0
        self._misses = 0
        self._lock = threading.Lock()
        self.metrics = metrics  # obs.MetricsRegistry or None

    def _report(self, hits: int, misses: int) -> None:
        m = self.metrics
        if m is None:
            return
        if hits:
            m.counter("solver_cache_hits_total").inc(hits)
        if misses:
            m.counter("solver_cache_misses_total").inc(misses)

    def get_or_build(self, key: Hashable, builder: Callable[[], Any],
                     occupants: int = 1) -> Tuple[Any, bool]:
        """Return ``(solver, was_cached)`` for ``key``.

        ``occupants`` is the number of graphs riding this launch; all of
        them except the one paying a fresh build count as hits.
        """
        with self._lock:
            cached = self._store.get(key)
            if cached is not None:
                self._hits += occupants
        if cached is not None:
            self._report(occupants, 0)
            return cached, True
        solver = builder()  # build outside the lock: tracing can be slow
        with self._lock:
            cached = self._store.get(key)
            if cached is not None:  # lost a race; the built copy is discarded
                self._hits += occupants
            else:
                self._store[key] = solver
                self._misses += 1
                self._hits += max(occupants - 1, 0)
        if cached is not None:
            self._report(occupants, 0)
            return cached, True
        self._report(max(occupants - 1, 0), 1)
        return solver, False

    def info(self) -> CacheInfo:
        with self._lock:
            return CacheInfo(hits=self._hits, misses=self._misses,
                             size=len(self._store))

    def keys(self):
        with self._lock:
            return sorted(self._store, key=repr)

    def evict(self, prefix: Hashable) -> int:
        """Drop every entry whose key equals ``prefix`` or is a tuple
        starting with it (``GraphSession.invalidate`` evicts all views of
        one snapshot this way).  Counters are kept — eviction is not a
        reset.  Returns the number of entries dropped."""
        with self._lock:
            doomed = [k for k in self._store
                      if k == prefix
                      or (isinstance(k, tuple) and k and k[0] == prefix)]
            for k in doomed:
                del self._store[k]
            return len(doomed)

    def clear(self) -> None:
        with self._lock:
            self._store.clear()
            self._hits = 0
            self._misses = 0
