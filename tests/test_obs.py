"""Observability subsystem: tracer, metrics registry, exporters, and the
engine wiring (per-graph solve_many attribution, disabled-path invariants).
"""
import json
import threading

import numpy as np
import pytest

from repro.ampc import AmpcEngine
from repro.graph import generators as gen
from repro.obs import (NOOP_TRACER, MetricsRegistry, Tracer, current_tracer,
                       set_default_tracer)
from repro.obs.export import (coverage, to_chrome_trace, write_chrome_trace,
                              write_jsonl)
from repro.obs.metrics import ENGINE_METRICS
from repro.obs.trace import NOOP_SPAN
from repro.runtime.retry import resilient_call


# ---------------------------------------------------------------- tracer
def test_span_nesting_and_attributes():
    tr = Tracer()
    with tr.span("outer", phase="a") as outer:
        outer.set(extra=1)
        with tr.span("inner"):
            pass
        with tr.span("inner"):
            pass
    roots = tr.spans()
    assert [r.name for r in roots] == ["outer"]
    assert [c.name for c in roots[0].children] == ["inner", "inner"]
    assert roots[0].attributes == {"phase": "a", "extra": 1}
    assert roots[0].dur_us >= max(c.dur_us for c in roots[0].children)
    assert len(roots[0].find("inner")) == 2


def test_span_error_annotation():
    tr = Tracer()
    with pytest.raises(RuntimeError):
        with tr.span("boom"):
            raise RuntimeError("x")
    [sp] = tr.spans()
    assert sp.attributes["error"] == "RuntimeError"


def test_threaded_collection_keeps_stacks_separate():
    tr = Tracer()
    barrier = threading.Barrier(4)

    def worker(i):
        barrier.wait()
        with tr.span(f"w{i}"):
            with tr.span("child"):
                pass

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    roots = tr.spans()
    # one root per thread, each with exactly its own child (no cross-thread
    # nesting even though all four traced concurrently)
    assert sorted(r.name for r in roots) == ["w0", "w1", "w2", "w3"]
    assert all(len(r.children) == 1 and r.children[0].name == "child"
               for r in roots)
    tids = {r.thread_id for r in roots}
    assert len(tids) == 4


def test_record_span_retroactive_parenting():
    tr = Tracer()
    with tr.span("launch") as sp:
        pass
    g = tr.record_span("graph[0]", dur_s=0.25, parent=sp, slot=0)
    assert sp.children == [g]
    assert g.dur_us == 250_000
    # without an explicit parent and no open span, it becomes a root
    r = tr.record_span("orphan", dur_s=0.1)
    assert r in tr.spans()


def test_noop_tracer_fast_path_is_allocation_free():
    assert NOOP_TRACER.enabled is False
    assert NOOP_TRACER.span("x", a=1) is NOOP_SPAN
    assert NOOP_TRACER.record_span("y", dur_s=1.0) is NOOP_SPAN
    with NOOP_TRACER.span("x") as sp:
        assert sp is NOOP_SPAN
        sp.event("e")
        assert sp.set(a=1) is NOOP_SPAN
    assert NOOP_TRACER.spans() == []
    assert NOOP_TRACER.all_spans() == []


def test_current_tracer_follows_open_spans():
    assert current_tracer() is NOOP_TRACER
    tr = Tracer()
    with tr.span("outer"):
        assert current_tracer() is tr
        tr.event("note", level="WARN", k=1)
    assert current_tracer() is NOOP_TRACER
    [sp] = tr.spans()
    assert sp.events[0].name == "note"
    assert sp.events[0].level == "WARN"


# ---------------------------------------------------------------- export
def test_chrome_trace_roundtrip():
    tr = Tracer()
    with tr.span("solve", problem="mis"):
        with tr.span("shuffle:phase", nbytes=128) as sp:
            sp.event("dht_queries", queries=7)
    doc = json.loads(json.dumps(to_chrome_trace(tr)))
    evs = doc["traceEvents"]
    complete = [e for e in evs if e["ph"] == "X"]
    instants = [e for e in evs if e["ph"] == "i"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert {e["name"] for e in complete} == {"solve", "shuffle:phase"}
    for e in complete:
        assert isinstance(e["ts"], int) and isinstance(e["dur"], int)
        assert e["dur"] >= 0 and e["pid"] and e["tid"]
    assert instants[0]["name"] == "dht_queries"
    assert instants[0]["args"]["queries"] == 7
    assert meta and meta[0]["args"]["name"] == "main"
    assert doc["displayTimeUnit"] == "ms"


def test_chrome_trace_file_and_jsonl(tmp_path):
    tr = Tracer()
    with tr.span("a"):
        with tr.span("b"):
            pass
    p = tmp_path / "trace.json"
    doc = write_chrome_trace(str(p), tr, extra_meta={"k": "v"})
    on_disk = json.loads(p.read_text())
    assert on_disk == json.loads(json.dumps(doc))
    assert on_disk["otherData"] == {"k": "v"}
    jl = tmp_path / "spans.jsonl"
    n = write_jsonl(str(jl), tr)
    lines = [json.loads(line) for line in jl.read_text().splitlines()]
    assert n == len(lines) == 2
    child = next(ln for ln in lines if ln["name"] == "b")
    parent = next(ln for ln in lines if ln["name"] == "a")
    assert child["parent_id"] == parent["span_id"]


def test_coverage_fraction():
    tr = Tracer()
    with tr.span("root"):
        pass
    [sp] = tr.spans()
    assert coverage(tr, sp.dur_us) == pytest.approx(1.0)
    assert coverage(tr, sp.dur_us * 2) == pytest.approx(0.5)


# ---------------------------------------------------------------- metrics
def test_metrics_label_aggregation():
    reg = MetricsRegistry()
    c = reg.counter("dht_queries_total", labelnames=("algorithm",))
    c.inc(3, algorithm="ampc_mis")
    c.inc(2, algorithm="ampc_mis")
    c.inc(5, algorithm="ampc_msf")
    assert c.value(algorithm="ampc_mis") == 5
    assert c.value(algorithm="ampc_msf") == 5
    # same name resolves to the same metric; mismatches are rejected
    assert reg.counter("dht_queries_total",
                       labelnames=("algorithm",)) is c
    with pytest.raises(ValueError):
        reg.counter("dht_queries_total", labelnames=("other",))
    with pytest.raises(ValueError):
        reg.gauge("dht_queries_total", labelnames=("algorithm",))
    with pytest.raises(ValueError):
        c.inc(1)  # missing the algorithm label
    h = reg.histogram("solve_latency_s", labelnames=("problem", "backend"))
    h.observe(0.1, problem="mis", backend="local")
    h.observe(0.3, problem="mis", backend="local")
    st = h.stats(problem="mis", backend="local")
    assert st["count"] == 2 and st["sum"] == pytest.approx(0.4)
    rep = reg.report()
    assert 'dht_queries_total{algorithm="ampc_mis"}  5' in rep
    assert "solve_latency_s" in rep


# ------------------------------------------------------------ engine wiring
def test_solve_outputs_bit_identical_with_tracing_on_vs_off():
    g = gen.erdos_renyi(64, 3.0, seed=3)
    reg = MetricsRegistry()
    res_on = AmpcEngine(seed=0, trace=True, metrics=reg).solve(g, "mis")
    res_off = AmpcEngine(seed=0, trace=False, metrics=False).solve(g, "mis")
    assert np.array_equal(np.asarray(res_on.output),
                          np.asarray(res_off.output))
    for key in ("shuffles", "bytes_shuffled", "dht_queries", "dht_bytes",
                "dht_query_waves", "dedup_savings", "dht_overflows"):
        assert res_on.ledger[key] == res_off.ledger[key], key
    assert res_on.trace is not None and res_off.trace is None


def test_solve_span_tree_and_metrics():
    reg = MetricsRegistry()
    eng = AmpcEngine(seed=0, trace=True, metrics=reg)
    res = eng.solve(gen.erdos_renyi(48, 3.0, seed=1), "mis")
    sp = res.trace
    assert sp.name == "solve"
    assert sp.attributes["problem"] == "mis"
    shuffles = [c for c in sp.children if c.name.startswith("shuffle:")]
    assert len(shuffles) == res.shuffles
    # dht lookups nest inside the solve span
    assert sp.find("dht:lookup")
    assert reg.counter("shuffles_total", labelnames=("algorithm",)) \
        .value(algorithm="ampc_mis") == res.shuffles
    assert reg.histogram("solve_latency_s",
                         labelnames=("problem", "backend")) \
        .stats(problem="mis", backend="local")["count"] == 1


def test_solve_many_per_graph_trace_matches_ledger_shares():
    eng = AmpcEngine(seed=0, trace=True, metrics=False)
    fleet = [gen.erdos_renyi(48, 3.0, seed=s) for s in range(3)]
    results = eng.solve_many(fleet, "mis")
    [root] = [r for r in eng.tracer.spans() if r.name == "solve_many"]
    buckets = [c for c in root.children if c.name == "bucket"]
    assert buckets, "bucket launches must nest under solve_many"
    graph_spans = [c for b in buckets for c in b.children
                   if c.name.startswith("graph[")]
    assert len(graph_spans) == len(fleet)
    for idx, res in enumerate(results):
        sp = res.trace
        assert sp is not None and sp.name == f"graph[{idx}]"
        assert sp in graph_spans
        # the span's shuffle children are exactly the ledger's phase_times
        # shares recorded through RoundLedger.record_shuffle
        by_name = {c.name: c for c in sp.children}
        phases = res.raw_ledger.phase_times
        assert set(by_name) == {f"shuffle:{p}" for p in phases}
        for phase, secs in phases.items():
            assert by_name[f"shuffle:{phase}"].dur_us == int(secs * 1e6)
    # the batched DHT exchange attaches to the bucket via the ambient tracer
    assert root.find("dht:lookup_many")


def test_solve_many_gates_ledger_events_by_default():
    eng = AmpcEngine(seed=0, trace=False, metrics=False)
    fleet = [gen.erdos_renyi(48, 3.0, seed=s) for s in range(2)]
    batched = eng.solve_many(fleet, "mis")
    assert all(r.raw_ledger.events == [] for r in batched)
    assert all(r.raw_ledger.shuffles > 0 for r in batched)   # still counted
    single = eng.solve(fleet[0], "mis")
    assert single.raw_ledger.events                          # solve keeps them
    kept = eng.solve_many(fleet, "mis", record_events=True)
    assert all(r.raw_ledger.events for r in kept)


def test_default_tracer_inherited_by_engines():
    tr = Tracer()
    set_default_tracer(tr)
    try:
        eng = AmpcEngine(seed=0, metrics=False)   # trace=None -> default
        res = eng.solve(gen.erdos_renyi(32, 2.0, seed=1), "mis")
        assert res.trace is not None
        assert res.trace in tr.spans()
    finally:
        set_default_tracer(None)
    eng = AmpcEngine(seed=0, metrics=False)
    assert eng.solve(gen.erdos_renyi(32, 2.0, seed=1), "mis").trace is None


# ---------------------------------------------------------------- retry
def test_retry_counts_metric_and_emits_warn_event():
    from repro.obs.metrics import default_registry

    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] == 1:
            raise ValueError("RESOURCE_EXHAUSTED: injected")
        return 42

    reg = default_registry()
    before = reg.counter("retry_transients_total",
                         labelnames=("marker",)).value(
                             marker="RESOURCE_EXHAUSTED")
    tr = Tracer()
    with tr.span("solve"):
        assert resilient_call(flaky) == 42
    after = reg.counter("retry_transients_total",
                        labelnames=("marker",)).value(
                            marker="RESOURCE_EXHAUSTED")
    assert after == before + 1
    [sp] = tr.spans()
    [ev] = [e for e in sp.events if e.name == "transient_retry"]
    assert ev.level == "WARN"
    assert ev.attributes["marker"] == "RESOURCE_EXHAUSTED"
    assert ev.attributes["attempt"] == 1


def test_engine_metrics_report_and_disabled():
    reg = MetricsRegistry()
    eng = AmpcEngine(seed=0, metrics=reg)
    eng.solve(gen.erdos_renyi(32, 2.0, seed=1), "mis")
    rep = eng.metrics_report()
    assert "solves_total" in rep and "shuffles_total" in rep
    assert AmpcEngine(seed=0, metrics=False).metrics_report() == \
        "(metrics disabled)"


def test_engine_metric_names_are_canonical():
    """Every metric the engine stack emits must be declared in
    ENGINE_METRICS (the table the docs are checked against)."""
    reg = MetricsRegistry()
    eng = AmpcEngine(seed=0, trace=True, metrics=reg)
    fleet = [gen.erdos_renyi(48, 3.0, seed=s) for s in range(2)]
    eng.solve_many(fleet, "mis")
    eng.solve(fleet[0], "mis")
    for name, metric in reg.metrics().items():
        assert name in ENGINE_METRICS, f"undeclared metric {name}"
        assert ENGINE_METRICS[name].kind == metric.kind
        assert ENGINE_METRICS[name].labels == metric.labelnames
