"""jit wrapper with impl switch for segment_matmul."""
from __future__ import annotations

from .kernel import segment_matmul_pallas
from .ref import segment_matmul_ref


def segment_matmul(x, nbr, w, impl: str = "pallas", interpret: bool = True,
                   block_n: int = 8):
    if impl == "pallas":
        return segment_matmul_pallas(x, nbr, w, block_n=block_n,
                                     interpret=interpret)
    return segment_matmul_ref(x, nbr, w)
