"""Batched serving example: prefill a batch of prompts, decode with a KV
cache (ring buffer under sliding-window configs), report throughput.

  PYTHONPATH=src python examples/serve_lm.py --arch gemma3-12b --gen 24
"""
import argparse

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-12b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()
    r = serve(args.arch, smoke=True, batch=args.batch,
              prompt_len=args.prompt_len, gen=args.gen)
    print(f"batch={args.batch} prompt={args.prompt_len} gen={args.gen}")
    print(f"prefill: {r['prefill_s']:.2f}s   decode: {r['decode_s']:.2f}s "
          f"({r['decode_tok_s']:.1f} tok/s)")
    print(f"sample continuation ids: {r['generated'][0][:10].tolist()}")


if __name__ == "__main__":
    main()
