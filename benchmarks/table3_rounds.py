"""Table 3 reproduction: shuffles (costly rounds) used by AMPC vs MPC
implementations of MIS / MaximalMatching / MSF (+ connectivity)."""
from __future__ import annotations

from repro.core import matching as mm, mis, msf, connectivity as cc
from repro.core.rounds import RoundLedger

from .common import GRAPHS, fmt_table


def run(graph_names=None):
    rows = []
    names = graph_names or list(GRAPHS)
    algs = [
        ("AMPC MIS", lambda g, led: mis.mis_ampc(g, seed=0, ledger=led)),
        ("AMPC MM", lambda g, led: mm.mm_ampc(g, seed=0, ledger=led)),
        ("AMPC MSF", lambda g, led: msf.msf_ampc(
            g.with_random_weights(0), seed=0, ledger=led,
            skip_ternarize_if_dense=False)),
        ("AMPC CC", lambda g, led: cc.cc_ampc(g, seed=0, ledger=led)),
        ("MPC MIS", lambda g, led: mis.mis_mpc_rootset(g, seed=0, ledger=led)),
        ("MPC MM", lambda g, led: mm.mm_mpc_rootset(g, seed=0, ledger=led)),
        ("MPC MSF", lambda g, led: msf.msf_mpc_boruvka(
            g.with_random_weights(0), seed=0, ledger=led)),
        ("MPC CC", lambda g, led: cc.cc_mpc_hash_to_min(g, ledger=led)),
    ]
    table = {}
    for gname in names:
        g = GRAPHS[gname]()
        for aname, fn in algs:
            led = RoundLedger(aname)
            fn(g, led)
            table.setdefault(aname, {})[gname] = led.shuffles
    rows = [[aname] + [table[aname][g] for g in names] for aname, _ in algs]
    out = fmt_table(["Algorithm (shuffles)"] + names, rows)
    print(out)
    return {"table": table, "markdown": out}


if __name__ == "__main__":
    run()
