"""DHT primitive: dedup caching + lookup semantics (+hypothesis properties)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import dht
from repro.core.rounds import RoundLedger


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 49), min_size=1, max_size=120))
def test_dedup_keys_roundtrip(keys):
    k = jnp.asarray(np.array(keys, np.int32))
    uniq, inv, n_unique = dht.dedup_keys(k)
    uniq, inv = np.asarray(uniq), np.asarray(inv)
    assert int(n_unique) == len(set(keys))
    # reconstruction: uniq[inv] == keys
    assert np.array_equal(uniq[inv], np.array(keys))
    # uniq prefix is sorted and distinct
    pref = uniq[:int(n_unique)]
    assert np.array_equal(pref, np.unique(np.array(keys)))


def test_lookup_matches_take():
    values = jnp.asarray(np.random.default_rng(0).random((64, 3)).astype(np.float32))
    keys = jnp.asarray(np.array([3, 3, 7, 0, 63, 7, 7], np.int32))
    out, nuniq = dht.lookup(values, keys, dedup=True)
    ref = np.asarray(values)[np.array([3, 3, 7, 0, 63, 7, 7])]
    assert np.allclose(np.asarray(out), ref)
    assert int(nuniq) == 4


def test_lookup_negative_keys_are_padding():
    values = jnp.asarray(np.arange(10, dtype=np.float32))
    keys = jnp.asarray(np.array([2, -1, 5], np.int32))
    out, nuniq = dht.lookup(values, keys, dedup=True)
    assert int(nuniq) == 2  # padding not counted
    assert float(out[0]) == 2.0 and float(out[2]) == 5.0


def test_sharded_dht_ledger_accounting():
    led = RoundLedger("t")
    values = jnp.asarray(np.zeros((32, 4), np.float32))
    d = dht.ShardedDHT(values, ledger=led)
    keys = jnp.asarray(np.array([1, 1, 1, 2], np.int32))
    d.lookup(keys)
    assert led.dht_queries == 2          # deduped
    assert led.dedup_savings == 2        # 4 - 2
    d.lookup(keys, dedup=False)
    assert led.dht_queries == 2 + 4


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 6), st.lists(st.integers(0, 1000), min_size=1,
                                   max_size=60))
def test_dedup_savings_never_negative(nvals, keys):
    values = jnp.asarray(np.arange(1024, dtype=np.float32))
    k = jnp.asarray(np.array(keys, np.int32) % 1024)
    out_d, nu = dht.lookup(values, k, dedup=True)
    out_n, nn = dht.lookup(values, k, dedup=False)
    assert np.allclose(np.asarray(out_d), np.asarray(out_n))
    assert int(nu) <= int(nn)
