"""Quickstart: the paper's algorithms on a social-network-like graph through
the unified ``AmpcEngine`` session API (Table 3 in miniature).

One engine serves every problem; each ``solve`` returns an ``AmpcResult``
whose ``ledger`` carries the AMPC-vs-MPC round/byte accounting that used to
require hand-threading a ``RoundLedger`` per call.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.ampc import AmpcEngine
from repro.core import oracle
from repro.graph import generators as gen


def main():
    g = gen.rmat(12, 8.0, seed=0)
    print(f"graph: n={g.n} m={g.m} (RMAT, power-law)")
    eng = AmpcEngine(dht_backend="local", epsilon=0.5, seed=0)

    # --- MIS
    ra = eng.solve(g, "mis")
    rm = eng.solve(g, "mis-mpc")
    assert np.array_equal(ra.output, rm.output), "same randomness => same MIS"
    print(f"\nMIS: |I|={ra.output.sum()}  AMPC shuffles={ra.shuffles} "
          f"(cache saved {ra.stats['cache_savings_factor']:.1f}x queries)  "
          f"MPC shuffles={rm.shuffles}")

    # --- Maximal matching
    rmm = eng.solve(g, "matching")
    print(f"MM : |M|={rmm.output.sum()}  AMPC shuffles={rmm.shuffles}  "
          f"maximal={oracle.is_maximal_matching(g, rmm.output)}")

    # --- MSF (degree weights, Section 5.2)
    gw = g.with_degree_weights()
    rf = eng.solve(gw, "msf", skip_ternarize_if_dense=False)
    rfm = eng.solve(gw, "msf-mpc")
    print(f"MSF: weight={gw.weights[rf.output].sum():.0f}  AMPC shuffles="
          f"{rf.shuffles} "
          f"(queries/vertex={rf.stats['avg_queries_per_vertex']:.1f})"
          f"  MPC shuffles={rfm.shuffles} "
          f"({rfm.stats['phases']} Borůvka phases)")

    # --- 1-vs-2 cycle
    for name, cyc, expect in [("one", gen.one_cycle(20000), 1),
                              ("two", gen.two_cycles(10000), 2)]:
        ra = eng.solve(cyc, "one-vs-two", p=1 / 64)
        rm = eng.solve(cyc, "one-vs-two-mpc")
        print(f"1v2c({name}): AMPC says {ra.output} in {ra.shuffles} "
              f"shuffles; MPC says {rm.output} in "
              f"{3 * rm.stats['phases']} shuffles")
        assert ra.output == rm.output == expect

    # --- connectivity
    parts = gen.disjoint_components([3000, 2000, 1000], 4.0, seed=1)
    rc = eng.solve(parts, "connectivity")
    print(f"CC : {rc.stats['num_components']} components (expected 3)")


if __name__ == "__main__":
    main()
