"""Connected components in O(1) adaptive rounds (paper Theorem 1).

The paper obtains connectivity from MSF: compute any spanning forest, then
apply forest connectivity (Proposition 3.2).  ``cc_ampc`` runs the same
5-shuffle pipeline as ``msf_ampc`` on unit weights (edge-id tie-broken) and
composes the two contraction maps into per-vertex component labels.

``cc_mpc_hash_to_min`` is the MPC baseline: min-label propagation with one
materialized launch per phase (the CC-LocalContraction stand-in used for the
1-vs-2-cycle comparison in Section 5.6).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.coo import UGraph
from .rounds import RoundLedger


def _canonicalize(labels: np.ndarray) -> np.ndarray:
    """Relabel components by their minimum vertex id (oracle convention).
    Label values may live in any id space (e.g. ternarized vertices)."""
    n = labels.shape[0]
    _, inv = np.unique(labels, return_inverse=True)
    rep = np.full(inv.max() + 1, n, np.int64)
    np.minimum.at(rep, inv, np.arange(n))
    return rep[inv]


# --------------------------------------------------------------------------
# MPC baseline: min-label propagation (hash-to-min), one launch per phase
# --------------------------------------------------------------------------
@jax.jit
def _h2m_phase(u, v, labels):
    lu, lv = labels[u], labels[v]
    mn = jnp.minimum(lu, lv)
    n = labels.shape[0]
    new = labels
    new = new.at[u].min(mn)
    new = new.at[v].min(mn)
    new = new.at[lu].min(mn)   # hash-to-min: also hook the current root
    new = new.at[lv].min(mn)
    new = jnp.take(new, new)   # shortcut
    changed = jnp.any(new != labels)
    return new, changed


# --------------------------------------------------------------------------
# Batched-solve core: masked min-label propagation run to fixpoint in-round
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("n",))
def _cc_fixpoint_masked(u, v, edge_ok, n: int):
    """Connected-component labels by in-round min-label doubling.

    The vmappable core behind the batched ``solve_many`` connectivity path:
    all hash-to-min phases run against the same immutable snapshot inside
    one ``while_loop`` (AMPC adaptivity), instead of the 5-shuffle truncated
    Prim pipeline of the sequential solver.  ``edge_ok`` masks the padding
    lanes of a shape bucket; padding vertices keep their own ids and are
    sliced away by the caller.  Labels are constant per component at the
    fixpoint (callers canonicalize), so the final output matches the
    sequential solver's exactly after ``_canonicalize``.

    Returns (labels(n,) int32, iters, queries_nodedup, queries_dedup).
    Query model: each phase, every live edge reads both endpoint labels from
    the snapshot (no-dedup count); with per-machine caching each distinct
    endpoint is fetched once per wave.
    """
    labels0 = jnp.arange(n, dtype=jnp.int32)
    su = jnp.where(edge_ok, u, n)
    sv = jnp.where(edge_ok, v, n)
    scanned_per_wave = 2 * edge_ok.sum()
    probe = jnp.zeros((n,), jnp.int32)
    probe = probe.at[su].set(1, mode="drop")
    probe = probe.at[sv].set(1, mode="drop")
    distinct_per_wave = probe.sum()

    def cond(s):
        labels, it, q0, q1, changed = s
        return changed

    def body(s):
        labels, it, q0, q1, live = s
        lu, lv = labels[u], labels[v]
        mn = jnp.minimum(lu, lv)
        new = labels
        new = new.at[su].min(mn, mode="drop")
        new = new.at[sv].min(mn, mode="drop")
        new = new.at[jnp.where(edge_ok, lu, n)].min(mn, mode="drop")
        new = new.at[jnp.where(edge_ok, lv, n)].min(mn, mode="drop")
        new = jnp.take(new, new)   # shortcut
        changed = jnp.any(new != labels)
        # gate counters on the lane being live: a converged lane of a
        # vmapped solve_many bucket may still execute the body
        inc = live.astype(jnp.int32)
        return (new, it + inc, q0 + inc * scanned_per_wave,
                q1 + inc * distinct_per_wave, changed)

    labels, iters, q0, q1, _ = jax.lax.while_loop(
        cond, body,
        (labels0, jnp.int32(0), jnp.int32(0), jnp.int32(0),
         jnp.asarray(True)))
    return labels, iters, q0, q1


def cc_ampc(g: UGraph, epsilon: float = 0.5, seed: int = 0,
            ledger: Optional[RoundLedger] = None) -> Tuple[np.ndarray, dict]:
    """Deprecated shim over repro.ampc.solvers.cc_ampc."""
    from ..ampc import solvers
    from ..ampc.deprecation import warn_once
    warn_once("repro.core.connectivity.cc_ampc",
              'AmpcEngine().solve(g, "connectivity")')
    return solvers.cc_ampc(g, epsilon=epsilon, seed=seed, ledger=ledger)


def cc_mpc_hash_to_min(g: UGraph, ledger: Optional[RoundLedger] = None,
                       max_phases: int = 200) -> Tuple[np.ndarray, dict]:
    """Deprecated shim over repro.ampc.solvers.cc_mpc_hash_to_min."""
    from ..ampc import solvers
    from ..ampc.deprecation import warn_once
    warn_once("repro.core.connectivity.cc_mpc_hash_to_min",
              'AmpcEngine().solve(g, "connectivity-mpc")')
    return solvers.cc_mpc_hash_to_min(g, ledger=ledger,
                                      max_phases=max_phases)
