"""Batched multi-graph serving: ``solve_many`` vs a looped ``solve``.

The serving claim behind ``AmpcEngine.solve_many``: a fleet of mixed-size
graphs padded into power-of-two shape buckets touches only a handful of
compiled programs, and one vmapped launch per bucket amortizes tracing,
dispatch, and DHT exchange across every occupant.  The looped baseline pays
one trace per *distinct graph shape* plus one launch sequence per graph.

Reported per problem: per-graph latency of the looped baseline vs the first
(``cold``, compiles per bucket) and second (``warm``, pure cache hits)
``solve_many`` pass, plus the engine's solver-cache hit rate.
"""
from __future__ import annotations

import time

import numpy as np

from repro.ampc import AmpcEngine
from repro.graph import generators as gen
from repro.graph.batching import bucketize
from repro.obs import NOOP_TRACER

from .common import fmt_table
from .registry import bench

# mixed-size fleet: sizes drawn to span a few buckets with repeats inside
# each bucket (the serving-traffic shape the cache is built for)
FLEET_SIZES = [50, 60, 100, 120, 70, 50, 90, 110, 55, 65, 95, 115, 75, 85,
               105, 125]


def _fleet(fleet_size: int):
    sizes = [FLEET_SIZES[i % len(FLEET_SIZES)] for i in range(fleet_size)]
    return [gen.erdos_renyi(n, 4.0, seed=i) for i, n in enumerate(sizes)]


def _disabled_tracer_overhead(fleet, prob, t_warm):
    """Upper-bound what the observability hooks cost a warm ``solve_many``
    pass with tracing *disabled*: count the span/event ops an enabled warm
    pass emits, multiply by the measured cost of one no-op tracer call
    (the disabled path does strictly less — most hooks are guarded behind
    a single ``tracer.enabled`` attribute check)."""
    eng = AmpcEngine(seed=0, trace=True, metrics=False)
    eng.solve_many(fleet, prob)         # compile into this engine's cache
    eng.tracer.clear()
    eng.solve_many(fleet, prob)         # warm pass, every hook live
    spans = eng.tracer.all_spans()
    ops = len(spans) + sum(len(s.events) for s in spans)
    reps = 100_000
    t0 = time.perf_counter()
    for _ in range(reps):
        with NOOP_TRACER.span("x"):
            pass
    per_op = (time.perf_counter() - t0) / reps
    return ops, per_op, ops * per_op / max(t_warm, 1e-9)


@bench("solve_many",
       quick_kwargs={"problems": ["mis", "matching"], "fleet_size": 8},
       summary="solve_many vs looped solve(): per-graph latency on a "
               "mixed-size fleet")
def run(problems=None, fleet_size: int = 16):
    problems = problems or ["mis", "matching", "connectivity"]
    fleet = _fleet(fleet_size)
    buckets = bucketize(fleet)
    print(f"fleet: {len(fleet)} graphs in {len(buckets)} shape buckets "
          f"{sorted(buckets)}")
    rows = []
    speedups = {}
    warm_times = {}
    for prob in problems:
        eng = AmpcEngine(seed=0)   # fresh engine: cold solver cache
        t0 = time.perf_counter()
        seq = [eng.solve(g, prob) for g in fleet]
        t_loop = time.perf_counter() - t0
        t0 = time.perf_counter()
        cold = eng.solve_many(fleet, prob)
        t_cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        warm = eng.solve_many(fleet, prob)
        t_warm = time.perf_counter() - t0
        for s, c, w in zip(seq, cold, warm):
            assert np.array_equal(s.output, c.output), "batched != sequential"
            assert np.array_equal(s.output, w.output)
        info = eng.cache_info()
        n = len(fleet)
        speedups[prob] = t_loop / max(t_warm, 1e-9)
        warm_times[prob] = t_warm
        rows.append([prob, n,
                     f"{1e3 * t_loop / n:.1f}", f"{1e3 * t_cold / n:.1f}",
                     f"{1e3 * t_warm / n:.1f}",
                     f"{t_loop / max(t_cold, 1e-9):.1f}x",
                     f"{t_loop / max(t_warm, 1e-9):.1f}x",
                     f"{info.hit_rate:.2f}"])
    out = fmt_table(["problem", "graphs", "loop ms/g", "batched cold ms/g",
                     "batched warm ms/g", "speedup cold", "speedup warm",
                     "cache hit-rate"], rows)
    print(out)
    print("\nper-graph latency: one vmapped launch per shape bucket vs one "
          "launch sequence per graph; warm = compiled-solver cache hits only")
    probe = problems[0]
    ops, per_op, frac = _disabled_tracer_overhead(
        fleet, probe, warm_times[probe])
    print(f"\ndisabled-tracer overhead ({probe} warm pass): {ops} hook ops "
          f"x {per_op * 1e9:.0f}ns no-op = {100 * frac:.3f}% of "
          f"{1e3 * warm_times[probe]:.1f}ms")
    assert frac < 0.02, \
        f"disabled-tracer overhead {100 * frac:.2f}% exceeds the 2% budget"
    return {"rows": rows, "markdown": out, "speedups": speedups,
            "tracer_overhead_pct": 100 * frac,
            "buckets": {str(k): len(v) for k, v in buckets.items()}}


if __name__ == "__main__":
    run()
