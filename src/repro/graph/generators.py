"""Deterministic synthetic graph generators (numpy, host side).

Covers the paper's experimental families (2xk cycle graphs, social-network-like
power-law graphs) plus shapes needed by the assigned GNN architectures
(molecular point clouds, grids, Cora/Reddit/ogbn-products stand-ins).
"""
from __future__ import annotations

import numpy as np

from .coo import UGraph


def cycle(n: int, offset: int = 0) -> UGraph:
    u = np.arange(n, dtype=np.int32)
    v = (u + 1) % n
    return UGraph(n, np.stack([u + offset, v + offset], axis=1))


def two_cycles(k: int) -> UGraph:
    """The paper's 2xk family: two disjoint cycles of length k."""
    c1 = cycle(k)
    c2 = cycle(k, offset=k)
    return UGraph(2 * k, np.concatenate([c1.edges, c2.edges], axis=0))


def one_cycle(n: int) -> UGraph:
    return cycle(n)


def path(n: int) -> UGraph:
    u = np.arange(n - 1, dtype=np.int32)
    return UGraph(n, np.stack([u, u + 1], axis=1))


def star(n: int) -> UGraph:
    u = np.zeros(n - 1, np.int32)
    v = np.arange(1, n, dtype=np.int32)
    return UGraph(n, np.stack([u, v], axis=1))


def erdos_renyi(n: int, avg_deg: float, seed: int = 0) -> UGraph:
    rng = np.random.default_rng(seed)
    m = int(n * avg_deg / 2)
    e = rng.integers(0, n, size=(m, 2), dtype=np.int64).astype(np.int32)
    return UGraph(n, e).dedup()


def rmat(n_log2: int, avg_deg: float, seed: int = 0,
         a: float = 0.57, b: float = 0.19, c: float = 0.19) -> UGraph:
    """RMAT power-law generator (Graph500 parameters by default)."""
    n = 1 << n_log2
    m = int(n * avg_deg / 2)
    rng = np.random.default_rng(seed)
    src = np.zeros(m, np.int64)
    dst = np.zeros(m, np.int64)
    for level in range(n_log2):
        r = rng.random(m)
        # quadrant probabilities a, b, c, d
        go_right = r >= a + b  # bottom half for src bit
        r2 = rng.random(m)
        dst_bit = np.where(go_right, r2 >= c / max(c + (1 - a - b - c), 1e-9),
                           r2 >= a / max(a + b, 1e-9))
        src = src * 2 + go_right
        dst = dst * 2 + dst_bit
    e = np.stack([src, dst], axis=1).astype(np.int32)
    # permute labels so high-degree vertices are not clustered at small ids
    perm = rng.permutation(n).astype(np.int32)
    e = perm[e]
    return UGraph(n, e).dedup()


def grid2d(h: int, w: int) -> UGraph:
    idx = np.arange(h * w).reshape(h, w)
    horiz = np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], axis=1)
    vert = np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], axis=1)
    return UGraph(h * w, np.concatenate([horiz, vert]).astype(np.int32))


def random_geometric(n: int, radius: float, seed: int = 0, dim: int = 3):
    """Point cloud + radius graph; returns (graph, positions, species).

    Used for the molecular GNN architectures (SchNet / MACE).
    """
    rng = np.random.default_rng(seed)
    box = (n / 0.05) ** (1.0 / dim) * radius / 10.0 + radius
    pos = rng.random((n, dim)).astype(np.float32) * box
    d2 = ((pos[:, None, :] - pos[None, :, :]) ** 2).sum(-1)
    iu, iv = np.where(np.triu(d2 <= radius * radius, k=1))
    g = UGraph(n, np.stack([iu, iv], axis=1).astype(np.int32))
    species = rng.integers(0, 8, size=n).astype(np.int32)
    return g, pos, species


def disjoint_components(sizes, avg_deg: float = 4.0, seed: int = 0) -> UGraph:
    """Union of ER components with the given sizes (for connectivity tests)."""
    parts, off = [], 0
    for i, s in enumerate(sizes):
        g = erdos_renyi(s, avg_deg, seed=seed + i)
        # make each component connected by adding a spanning cycle
        cyc = cycle(s).edges
        parts.append(np.concatenate([g.edges, cyc]) + off)
        off += s
    return UGraph(off, np.concatenate(parts)).dedup()
