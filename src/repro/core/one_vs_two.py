"""1-vs-2-Cycle (paper Section 5.6) — the canonical AMPC-vs-MPC separation.

AMPC: sample vertices with probability p (paper uses 1/1024); each sampled
vertex *walks* the cycle by adaptive pointer chasing inside a single round
until it meets the next sampled vertex; the contracted cycle over samples is
then resolved by in-round doubling.  One shuffle writes the graph to the DHT;
one launch answers.

MPC baseline: pointer doubling with one materialized launch per phase —
Θ(log n) shuffles (the conjectured lower bound for this problem in MPC).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.coo import UGraph
from .rounds import RoundLedger


def cycle_adjacency(g: UGraph) -> np.ndarray:
    """(n,2) neighbour table; validates the graph is a disjoint cycle union."""
    deg = g.degrees()
    assert (deg == 2).all(), "1-vs-2-cycle input must be a union of cycles"
    nbr = np.full((g.n, 2), -1, np.int64)
    cnt = np.zeros(g.n, np.int64)
    for a, b in g.edges:
        nbr[a, cnt[a]] = b; cnt[a] += 1
        nbr[b, cnt[b]] = a; cnt[b] += 1
    return nbr.astype(np.int32)


@functools.partial(jax.jit, static_argnames=("max_steps",))
def _walk(nbr, sampled, ids, max_steps: int):
    """Each sampled vertex walks *outward in both directions* until the next
    sampled vertex (adaptive in-round pointer chasing)."""

    def walk(v, direction):
        start_next = nbr[v, direction]

        def cond(s):
            prev, cur, steps, done = s
            return ~done & (steps < max_steps)

        def body(s):
            prev, cur, steps, done = s
            nxt = jnp.where(nbr[cur, 0] == prev, nbr[cur, 1], nbr[cur, 0])
            return cur, nxt, steps + 1, sampled[nxt]

        prev, cur, steps, done = jax.lax.while_loop(
            cond, body, (v, start_next, jnp.int32(1), sampled[start_next]))
        return jnp.where(done, cur, -1), steps, done

    succ0, steps0, done0 = jax.vmap(lambda v: walk(v, 0))(ids)
    succ1, steps1, done1 = jax.vmap(lambda v: walk(v, 1))(ids)
    ok = jnp.all(jnp.where(sampled, done0 & done1, True))
    total_steps = jnp.where(sampled, steps0 + steps1, 0).sum()
    return succ0, succ1, total_steps, ok


@functools.partial(jax.jit, static_argnames=("n",))
def _count_components(succ0, succ1, sampled, ids, n: int):
    """Contracted graph: arcs (v, succ[v]) per direction for samples;
    components via in-round hook-and-contract."""
    from .msf import boruvka_core
    u_c = jnp.concatenate([ids, ids])
    v_c = jnp.concatenate([jnp.where(sampled & (succ0 >= 0), succ0, ids),
                           jnp.where(sampled & (succ1 >= 0), succ1, ids)])
    valid = jnp.concatenate([sampled, sampled]) & (u_c != v_c)
    w_c = jnp.arange(2 * n, dtype=jnp.float32)
    eid_c = jnp.arange(2 * n, dtype=jnp.int32)
    _, labels, _ = boruvka_core(u_c, v_c, w_c, eid_c, valid, n, 2 * n)
    seen = jnp.zeros((n,), jnp.int32).at[
        jnp.where(sampled, labels, n)].max(1, mode="drop")
    return seen.sum()


def _walk_and_count(nbr, sampled, max_steps: int):
    from ..runtime.retry import resilient_call
    n = nbr.shape[0]
    ids = jnp.arange(n, dtype=jnp.int32)
    succ0, succ1, total_steps, ok = resilient_call(
        _walk, nbr, sampled, ids, max_steps)
    ncomp = resilient_call(_count_components, succ0, succ1, sampled, ids, n)
    return ncomp, total_steps, ok


@functools.partial(jax.jit, static_argnames=("max_steps", "n"))
def _walk_and_count_batch(nbr_b, sampled_b, max_steps: int, n: int):
    """Vmapped walk + component count over a padded graph batch.

    ``nbr_b`` is (B, n, 2) with padding vertices self-looped
    (``nbr[v] = [v, v]``) and *marked sampled*, so each padding vertex costs
    exactly 2 walk steps and contributes exactly 1 component — callers
    subtract the padding counts per graph.  Real-cycle walks are unreachable
    from padding, so each lane reproduces the unpadded sequential walk.

    Returns (ncomp(B,), total_steps(B,), ok(B,)).
    """
    ids = jnp.arange(n, dtype=jnp.int32)

    def one(nbr, sampled):
        succ0, succ1, steps, ok = _walk(nbr, sampled, ids, max_steps)
        ncomp = _count_components(succ0, succ1, sampled, ids, n)
        return ncomp, steps, ok

    return jax.vmap(one)(nbr_b, sampled_b)


@jax.jit
def _local_contraction_phase(a, b, parent, alive, rank):
    """One CC-LocalContraction phase: remove rank-local-minima, reconnect
    their neighbours.  Self-loop vertices (a==b==self) are finished cycles."""
    n = a.shape[0]
    ids = jnp.arange(n, dtype=jnp.int32)
    finished = (a == ids) & (b == ids)
    act = alive & ~finished
    is_min = act & (rank < rank[a]) & (rank < rank[b])
    # 2-cycles (a==b!=self): the smaller-rank endpoint is the local min
    two = act & (a == b) & (a != ids)
    is_min = jnp.where(two, act & (rank < rank[a]), is_min)

    def other(x, u):
        """neighbour of x that is not u (for 2-cycles returns u itself,
        collapsing to a self-loop)."""
        return jnp.where(a[x] == u, b[x], a[x])

    # surviving vertices repoint through removed neighbours
    new_a = jnp.where(is_min[a], other(a, ids), a)
    new_b = jnp.where(is_min[b], other(b, ids), b)
    # removed vertices remember a surviving neighbour for label recovery
    parent = jnp.where(is_min, a, parent)
    # removed vertices become inert self-loops
    new_a = jnp.where(is_min, ids, new_a)
    new_b = jnp.where(is_min, ids, new_b)
    alive = alive & ~is_min
    remaining = (alive & ~((new_a == ids) & (new_b == ids))).sum()
    return new_a, new_b, parent, alive, remaining


def one_vs_two_ampc(g: UGraph, p: float = 1.0 / 64, seed: int = 0,
                    ledger: Optional[RoundLedger] = None,
                    max_steps: Optional[int] = None) -> Tuple[int, dict]:
    """Deprecated shim over repro.ampc.solvers.one_vs_two_ampc."""
    from ..ampc import solvers
    from ..ampc.deprecation import warn_once
    warn_once("repro.core.one_vs_two.one_vs_two_ampc",
              'AmpcEngine().solve(g, "one-vs-two")')
    return solvers.one_vs_two_ampc(g, p=p, seed=seed, ledger=ledger,
                                   max_steps=max_steps)


def one_vs_two_mpc(g: UGraph, seed: int = 0,
                   ledger: Optional[RoundLedger] = None) -> Tuple[int, dict]:
    """Deprecated shim over repro.ampc.solvers.one_vs_two_mpc."""
    from ..ampc import solvers
    from ..ampc.deprecation import warn_once
    warn_once("repro.core.one_vs_two.one_vs_two_mpc",
              'AmpcEngine().solve(g, "one-vs-two-mpc")')
    return solvers.one_vs_two_mpc(g, seed=seed, ledger=ledger)
