"""Maximal matching (paper Section 4, Theorem 2).

All variants compute the exact random-greedy (lexicographically-first)
maximal matching over a random edge permutation π — identical to the
sequential oracle.

``mm_ampc``            — the implementation evaluated in Section 5.4: one
  shuffle builds the edge-sorted graph + writes the DHT; one launch resolves
  all edges by an in-round dependency fixpoint (an edge joins when it is the
  minimum-rank unresolved edge at *both* endpoints; an edge dies when an
  endpoint is matched).  Per-vertex caching is modelled by the dedup query
  counters (the paper's per-vertex cache stores exactly the resolution
  frontier per vertex).

``mm_ampc_levels``     — Algorithm 4: O(log log Δ) levels of geometric edge
  sampling; level i materializes one launch.  Union of the level matchings is
  the LFMM of G (Lemma 4.4/4.5).

``mm_ampc_vertex_process`` — Theorem 2 part 2: per-vertex query budget n^ε
  per launch; frozen vertices postpone the resolution of their edges to the
  next launch; O(1/ε) launches empirically, total queries O(m + n^{1+ε}).

``mm_mpc_rootset``     — the MPC baseline of Section 5.4 (2 shuffles/phase).

The driver functions are deprecated shims over ``repro.ampc.solvers``; the
jitted fixpoint primitives (``_mm_wave``, ``_mm_fixpoint``) live here.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.coo import UGraph
from .rounds import RoundLedger

UNKNOWN, IN, OUT = 0, 1, 2
BIGF = jnp.float32(jnp.inf)


def _mm_wave(estatus, u, v, erank, n, active_edge=None):
    """One fixpoint wave. Returns (new_estatus, resolved_now)."""
    m = erank.shape[0]
    unk = estatus == UNKNOWN
    # endpoints already matched by earlier waves — their edges can never join
    prev_in = estatus == IN
    pmatch = jnp.zeros((n,), jnp.int32)
    pmatch = pmatch.at[jnp.where(prev_in, u, n)].set(1, mode="drop")
    pmatch = pmatch.at[jnp.where(prev_in, v, n)].set(1, mode="drop")
    wbig = jnp.where(unk, erank, BIGF)
    vmin = jax.ops.segment_min(jnp.concatenate([wbig, wbig]),
                               jnp.concatenate([u, v]),
                               num_segments=n)
    is_min = (unk & (pmatch[u] == 0) & (pmatch[v] == 0)
              & (erank <= vmin[u]) & (erank <= vmin[v]))
    if active_edge is not None:
        is_min = is_min & active_edge
    new = jnp.where(is_min, IN, estatus)
    matched = pmatch
    matched = matched.at[jnp.where(is_min, u, n)].set(1, mode="drop")
    matched = matched.at[jnp.where(is_min, v, n)].set(1, mode="drop")
    die = (new == UNKNOWN) & ((matched[u] == 1) | (matched[v] == 1))
    if active_edge is not None:
        die = die & active_edge
    new = jnp.where(die, OUT, new)
    return new, matched


@functools.partial(jax.jit, static_argnames=("n",))
def _mm_fixpoint(u, v, erank, n: int, estatus0):
    """Run the LFMM fixpoint to completion inside one program."""
    def cond(s):
        estatus, it, q0, q1 = s
        return jnp.any(estatus == UNKNOWN)

    def body(s):
        estatus, it, q0, q1 = s
        new, _ = _mm_wave(estatus, u, v, erank, n)
        unk = estatus == UNKNOWN
        # queries: each unresolved edge probes both endpoint frontiers
        scanned = 2 * unk.sum()
        probe = jnp.zeros((n,), jnp.int32)
        probe = probe.at[jnp.where(unk, u, n)].set(1, mode="drop")
        probe = probe.at[jnp.where(unk, v, n)].set(1, mode="drop")
        # gate the wave counter on live work so per-lane counts stay exact
        # when this fixpoint runs as one lane of a vmapped solve_many bucket
        live = unk.any().astype(jnp.int32)
        return new, it + live, q0 + scanned, q1 + probe.sum()

    estatus, iters, q0, q1 = jax.lax.while_loop(
        cond, body, (estatus0, jnp.int32(0), jnp.int32(0), jnp.int32(0)))
    return estatus, iters, q0, q1


# --------------------------------------------------------------------------
# Deprecated shims — the drivers moved to repro.ampc.solvers; prefer
# AmpcEngine().solve(g, "matching") and friends.
# --------------------------------------------------------------------------
def mm_ampc(g: UGraph, seed: int = 0,
            ledger: Optional[RoundLedger] = None,
            caching: bool = True,
            erank: "np.ndarray | None" = None) -> Tuple[np.ndarray, dict]:
    """Deprecated shim over repro.ampc.solvers.mm_ampc.

    ``erank`` is the Corollary-4.1 rank-injection point (weighted matching
    passes decreasing-weight ranks); omitted = random permutation from seed.
    """
    from ..ampc import solvers
    from ..ampc.deprecation import warn_once
    warn_once("repro.core.matching.mm_ampc",
              'AmpcEngine().solve(g, "matching")')
    return solvers.mm_ampc(g, seed=seed, ledger=ledger, caching=caching,
                           erank=erank)


def mm_ampc_levels(g: UGraph, seed: int = 0,
                   ledger: Optional[RoundLedger] = None) -> Tuple[np.ndarray, dict]:
    """Deprecated shim over repro.ampc.solvers.mm_ampc_levels."""
    from ..ampc import solvers
    from ..ampc.deprecation import warn_once
    warn_once("repro.core.matching.mm_ampc_levels",
              'AmpcEngine().solve(g, "matching-levels")')
    return solvers.mm_ampc_levels(g, seed=seed, ledger=ledger)


def mm_ampc_vertex_process(g: UGraph, epsilon: float = 0.5, seed: int = 0,
                           ledger: Optional[RoundLedger] = None,
                           ) -> Tuple[np.ndarray, dict]:
    """Deprecated shim over repro.ampc.solvers.mm_ampc_vertex_process."""
    from ..ampc import solvers
    from ..ampc.deprecation import warn_once
    warn_once("repro.core.matching.mm_ampc_vertex_process",
              'AmpcEngine().solve(g, "matching-vertex-process")')
    return solvers.mm_ampc_vertex_process(g, epsilon=epsilon, seed=seed,
                                          ledger=ledger)


def mm_mpc_rootset(g: UGraph, seed: int = 0,
                   ledger: Optional[RoundLedger] = None,
                   max_phases: int = 500) -> Tuple[np.ndarray, dict]:
    """Deprecated shim over repro.ampc.solvers.mm_mpc_rootset."""
    from ..ampc import solvers
    from ..ampc.deprecation import warn_once
    warn_once("repro.core.matching.mm_mpc_rootset",
              'AmpcEngine().solve(g, "matching-mpc")')
    return solvers.mm_mpc_rootset(g, seed=seed, ledger=ledger,
                                  max_phases=max_phases)
