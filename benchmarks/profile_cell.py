"""Hillclimb profiler: lower one cell, attribute FLOPs/bytes/collectives.

  PYTHONPATH=src python -m benchmarks.profile_cell --arch qwen2.5-32b \
      --shape train_4k

Registered with the harness as ``profile_cell`` (``benchmarks.run --only
profile_cell``).  Lowering against the production mesh needs
``--xla_force_host_platform_device_count=512``, which must be set before
jax initializes; the registered ``run()`` therefore re-invokes this module
in a subprocess instead of lowering in-process, so the harness's own jax
backend (already initialized with the default device count) is untouched.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from .registry import bench

_XLA_FLAG = "--xla_force_host_platform_device_count=512"


@bench("profile_cell",
       quick_kwargs={"arch": "gcn-cora", "shape": "full_graph_sm"},
       summary="lower one GNN cell on the production mesh; roofline-attribute "
               "FLOPs/bytes/collectives from the compiled HLO")
def run(arch: str = "gcn-cora", shape: str = "full_graph_sm",
        multi: bool = False, timeout: int = 600):
    cmd = [sys.executable, "-m", "benchmarks.profile_cell",
           "--arch", arch, "--shape", shape]
    if multi:
        cmd.append("--multi")
    env = dict(os.environ)
    env.setdefault("PYTHONPATH", "src")
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=timeout)
    print(proc.stdout, end="")
    if proc.returncode != 0:
        raise RuntimeError(
            f"profile_cell subprocess failed ({proc.returncode}):\n"
            f"{proc.stderr[-2000:]}")
    # first line block of stdout is the roofline-terms JSON object
    terms = json.loads(proc.stdout[:proc.stdout.index("}") + 1])
    return {"arch": arch, "shape": shape, "terms": terms}


def main():
    os.environ["XLA_FLAGS"] = (
        os.environ.get("_EXTRA_XLA_FLAGS", "") + " " + _XLA_FLAG).strip()
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--save-hlo", default="")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (int/bool/str)")
    args = ap.parse_args()
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        if v in ("True", "False"):
            overrides[k] = v == "True"
        else:
            try:
                overrides[k] = int(v)
            except ValueError:
                overrides[k] = v

    from repro.launch.mesh import make_production_mesh, n_chips
    from repro.launch.specs import build_lowerable
    from repro.launch.hlo import analyze_hlo, roofline_terms

    mesh = make_production_mesh(multi_pod=args.multi)
    low = build_lowerable(args.arch, args.shape, mesh,
                          overrides=overrides or None)
    compiled = low.lower(mesh).compile()
    txt = compiled.as_text()
    if args.save_hlo:
        open(args.save_hlo, "w").write(txt)
    a = analyze_hlo(txt)
    terms = roofline_terms(a, n_chips(mesh), low.model_flops)
    print(json.dumps({k: v for k, v in terms.items()
                      if not isinstance(v, dict)}, indent=1, default=str))
    print("\n-- top byte ops (per-device bytes) --")
    for op, b in a.top_byte_ops():
        print(f"  {b:12.4g}  {op}")
    print("\n-- top collective sites (per-device wire bytes) --")
    for site, b in a.top_collective_sites():
        print(f"  {b:12.4g}  {site}")
    mem = compiled.memory_analysis()
    print(f"\nmemory: args={mem.argument_size_in_bytes/1e9:.2f}GB "
          f"temp={mem.temp_size_in_bytes/1e9:.2f}GB")


if __name__ == "__main__":
    main()
