"""Shape-bucketed graph batching for ``AmpcEngine.solve_many``.

Serving many scenario graphs per call means one compiled program must fit
many input shapes.  The standard accelerator answer is *bucketing*: round
``(n, m)`` up to the next power of two, pad every graph in a bucket to that
shape, and vmap the solve over the batch dimension.  A fleet of mixed-size
graphs then touches only ``O(log)`` distinct compiled programs instead of
one per graph.

Padding conventions (consumed by the batch adapters in
``repro.ampc.solvers``):

  * padded **edges** are ``(0, 0)`` self-loops with ``edge_mask`` False —
    every batched fixpoint either masks them out explicitly or relies on
    self-loops being inert in its update rule;
  * padded **vertices** (ids ``n..n_bucket``) have no valid incident edges,
    so they resolve trivially and are sliced away by :func:`unpad`;
  * padded **weights** are ``+inf`` so they can never win a min-reduction.

Host-side only (numpy); the adapters convert to jnp at launch time.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .coo import UGraph


def next_pow2(x: int) -> int:
    """Smallest power of two >= max(x, 1)."""
    x = max(int(x), 1)
    return 1 << (x - 1).bit_length()


def bucket_shape(n: int, m: int) -> Tuple[int, int]:
    """The ``(n_bucket, m_bucket)`` a graph with ``n`` vertices and ``m``
    edges pads into: both sides rounded up to the next power of two."""
    return next_pow2(n), next_pow2(m)


@dataclasses.dataclass
class GraphBatch:
    """One shape bucket of a ``solve_many`` fleet, padded and stacked.

    ``indices[i]`` is the position of ``graphs[i]`` in the original fleet so
    results can be scattered back in input order.  ``edges`` / ``weights``
    are padded per the module conventions; ``edge_mask`` / ``node_mask``
    mark the real entries.
    """

    n_bucket: int
    m_bucket: int
    graphs: List[UGraph]
    indices: List[int]
    n: np.ndarray            # (B,) int32 actual vertex counts
    m: np.ndarray            # (B,) int32 actual edge counts
    edges: np.ndarray        # (B, m_bucket, 2) int32, padding = (0, 0)
    edge_mask: np.ndarray    # (B, m_bucket) bool
    node_mask: np.ndarray    # (B, n_bucket) bool
    weights: Optional[np.ndarray] = None  # (B, m_bucket) f32, padding = +inf

    def __len__(self) -> int:
        return len(self.graphs)

    @property
    def key(self) -> Tuple[int, int]:
        return (self.n_bucket, self.m_bucket)

    def padded_symmetric(self):
        """Batched doubled-directed view: (senders, receivers, edge_ok),
        each ``(B, 2 * m_bucket)``; padding lanes point at vertex 0 with
        ``edge_ok`` False."""
        B, mb = self.edges.shape[:2]
        senders = np.concatenate([self.edges[:, :, 0], self.edges[:, :, 1]],
                                 axis=1).astype(np.int32)
        receivers = np.concatenate([self.edges[:, :, 1], self.edges[:, :, 0]],
                                   axis=1).astype(np.int32)
        edge_ok = np.concatenate([self.edge_mask, self.edge_mask], axis=1)
        return senders, receivers, edge_ok


def pad_graphs(graphs: Sequence[UGraph], indices: Sequence[int],
               n_bucket: int, m_bucket: int) -> GraphBatch:
    """Stack ``graphs`` into one padded ``GraphBatch`` of the given bucket."""
    B = len(graphs)
    ns = np.array([g.n for g in graphs], np.int32)
    ms = np.array([g.m for g in graphs], np.int32)
    assert (ns <= n_bucket).all() and (ms <= m_bucket).all(), \
        "graph exceeds bucket shape"
    edges = np.zeros((B, m_bucket, 2), np.int32)
    edge_mask = np.zeros((B, m_bucket), bool)
    node_mask = np.zeros((B, n_bucket), bool)
    any_weights = any(g.weights is not None for g in graphs)
    weights = np.full((B, m_bucket), np.inf, np.float32) if any_weights else None
    for b, g in enumerate(graphs):
        edges[b, :g.m] = g.edges
        edge_mask[b, :g.m] = True
        node_mask[b, :g.n] = True
        if weights is not None and g.weights is not None:
            weights[b, :g.m] = g.weights
    return GraphBatch(n_bucket=n_bucket, m_bucket=m_bucket,
                      graphs=list(graphs), indices=list(indices),
                      n=ns, m=ms, edges=edges, edge_mask=edge_mask,
                      node_mask=node_mask, weights=weights)


def bucketize(graphs: Sequence[UGraph]) -> Dict[Tuple[int, int], GraphBatch]:
    """Group a fleet into padded shape buckets, preserving input order
    inside each bucket.  Returns ``{(n_bucket, m_bucket): GraphBatch}``."""
    groups: Dict[Tuple[int, int], Tuple[List[UGraph], List[int]]] = {}
    for i, g in enumerate(graphs):
        key = bucket_shape(g.n, g.m)
        gs, idx = groups.setdefault(key, ([], []))
        gs.append(g)
        idx.append(i)
    return {key: pad_graphs(gs, idx, *key)
            for key, (gs, idx) in groups.items()}


def unpad(row: np.ndarray, size: int) -> np.ndarray:
    """Slice one batch row back to its real length."""
    return np.asarray(row)[:size]
