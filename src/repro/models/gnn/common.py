"""GNN substrate: segment-op message passing over edge lists.

JAX has no CSR SpMM — message passing is gather (edge source features) →
edge transform → ``jax.ops.segment_sum`` scatter, exactly the DHT query-wave
pattern of the AMPC core (see DESIGN.md §4).  Batched small graphs use
padding + masks; large graphs shard the edge list across the mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class GraphBatch:
    """Padded, statically-shaped graph batch.

    senders/receivers: (E,) int32 (-pad edges point at node N, masked)
    node_feat: (N, F) float or None
    positions: (N, 3) float or None; species: (N,) int or None
    node_mask: (N,) bool; edge_mask: (E,) bool
    graph_ids: (N,) int32 (graph membership for readout); n_graphs: int
    labels: optional (N,) or (n_graphs,) int targets
    """
    senders: jnp.ndarray
    receivers: jnp.ndarray
    node_mask: jnp.ndarray
    edge_mask: jnp.ndarray
    graph_ids: jnp.ndarray
    n_graphs: int
    node_feat: Optional[jnp.ndarray] = None
    positions: Optional[jnp.ndarray] = None
    species: Optional[jnp.ndarray] = None
    labels: Optional[jnp.ndarray] = None

    @property
    def n_nodes(self) -> int:
        return int(self.node_mask.shape[0])


def scatter_sum(edge_vals, receivers, n_nodes, edge_mask=None):
    if edge_mask is not None:
        edge_vals = jnp.where(edge_mask[(...,) + (None,) * (edge_vals.ndim - 1)],
                              edge_vals, 0)
    return jax.ops.segment_sum(edge_vals, receivers, num_segments=n_nodes)


def gather(node_vals, idx):
    return jnp.take(node_vals, idx, axis=0)


def degree(receivers, n_nodes, edge_mask=None):
    ones = jnp.ones(receivers.shape[0], jnp.float32)
    return scatter_sum(ones, receivers, n_nodes, edge_mask)


def graph_readout(node_vals, graph_ids, n_graphs, node_mask, op="sum"):
    vals = jnp.where(node_mask[(...,) + (None,) * (node_vals.ndim - 1)],
                     node_vals, 0)
    s = jax.ops.segment_sum(vals, graph_ids, num_segments=n_graphs)
    if op == "sum":
        return s
    cnt = jax.ops.segment_sum(node_mask.astype(jnp.float32), graph_ids,
                              num_segments=n_graphs)
    return s / jnp.maximum(cnt[:, None], 1.0)


def init_linear(key, d_in, d_out, dtype=jnp.float32, bias=True):
    k1, _ = jax.random.split(key)
    p = {"w": jax.random.normal(k1, (d_in, d_out), dtype) / np.sqrt(d_in)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def init_mlp2(key, d_in, d_hidden, d_out, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {"l1": init_linear(k1, d_in, d_hidden, dtype),
            "l2": init_linear(k2, d_hidden, d_out, dtype)}


def mlp2(p, x, act=jax.nn.silu):
    return linear(p["l2"], act(linear(p["l1"], x)))
