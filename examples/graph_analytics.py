"""Single-linkage hierarchical clustering via MSF (the paper's flagship
application, Section 1: "one can use this algorithm together with a simple
sorting step, and our connectivity algorithm to find any desired level of a
single-linkage hierarchical clustering").

Builds a noisy point cloud with 4 planted clusters, computes the MSF of the
mutual-distance graph in constant adaptive rounds, cuts the heaviest edges,
and recovers the clusters with forest connectivity — both solves through
one ``AmpcEngine``.

  PYTHONPATH=src python examples/graph_analytics.py
"""
import numpy as np

from repro.ampc import AmpcEngine
from repro.graph.coo import UGraph


def make_clusters(k=4, per=150, spread=0.06, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.random((k, 2)) * 4.0
    pts = np.concatenate([c + rng.standard_normal((per, 2)) * spread
                          for c in centers])
    truth = np.repeat(np.arange(k), per)
    return pts.astype(np.float32), truth


def knn_graph(pts, k=8):
    d2 = ((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    np.fill_diagonal(d2, np.inf)
    nbr = np.argsort(d2, axis=1)[:, :k]
    rows = np.repeat(np.arange(len(pts)), k)
    cols = nbr.ravel()
    w = np.sqrt(d2[rows, cols]).astype(np.float32)
    g = UGraph(len(pts), np.stack([rows, cols], 1).astype(np.int32), w)
    return g.dedup()


def main():
    pts, truth = make_clusters()
    g = knn_graph(pts)
    print(f"kNN graph: n={g.n} m={g.m}")
    eng = AmpcEngine(seed=0)

    # 1) MSF in constant adaptive rounds
    res = eng.solve(g, "msf", skip_ternarize_if_dense=False)
    mask = res.output
    print(f"MSF edges: {mask.sum()} (queries/vertex "
          f"{res.stats['avg_queries_per_vertex']:.1f}, "
          f"{res.shuffles} shuffles)")

    # 2) "simple sorting step": cut the k-1 + noise heaviest MSF edges
    fe = np.where(mask)[0]
    order = fe[np.argsort(-g.weights[fe])]
    keep = np.ones(g.m, bool)
    keep[order[:3]] = False           # cut 3 heaviest => 4 clusters
    cut = mask & keep

    # 3) forest connectivity on the remaining forest
    forest = UGraph(g.n, g.edges[cut])
    labels = eng.solve(forest, "connectivity").output

    # score: purity of recovered clusters vs planted truth
    uniq = np.unique(labels)
    purity = 0
    for u in uniq:
        members = truth[labels == u]
        if len(members):
            purity += np.bincount(members).max()
    purity /= len(truth)
    print(f"clusters found: {len(uniq)} (planted 4); purity={purity:.3f}")
    assert purity > 0.95, "single-linkage clustering should recover clusters"
    print("OK")


if __name__ == "__main__":
    main()
