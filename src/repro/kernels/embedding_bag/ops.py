"""jit wrapper with impl switch for embedding_bag."""
from __future__ import annotations

from .kernel import embedding_bag_pallas
from .ref import embedding_bag_ref


def embedding_bag(table, ids, impl: str = "pallas", interpret: bool = True,
                  block_b: int = 8):
    if impl == "pallas":
        return embedding_bag_pallas(table, ids, block_b=block_b,
                                    interpret=interpret)
    return embedding_bag_ref(table, ids)
