"""Maximal matching (paper Section 4, Theorem 2).

All variants compute the exact random-greedy (lexicographically-first)
maximal matching over a random edge permutation π — identical to the
sequential oracle.

``mm_ampc``            — the implementation evaluated in Section 5.4: one
  shuffle builds the edge-sorted graph + writes the DHT; one launch resolves
  all edges by an in-round dependency fixpoint (an edge joins when it is the
  minimum-rank unresolved edge at *both* endpoints; an edge dies when an
  endpoint is matched).  Per-vertex caching is modelled by the dedup query
  counters (the paper's per-vertex cache stores exactly the resolution
  frontier per vertex).

``mm_ampc_levels``     — Algorithm 4: O(log log Δ) levels of geometric edge
  sampling; level i materializes one launch.  Union of the level matchings is
  the LFMM of G (Lemma 4.4/4.5).

``mm_ampc_vertex_process`` — Theorem 2 part 2: per-vertex query budget n^ε
  per launch; frozen vertices postpone the resolution of their edges to the
  next launch; O(1/ε) launches empirically, total queries O(m + n^{1+ε}).

``mm_mpc_rootset``     — the MPC baseline of Section 5.4 (2 shuffles/phase).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.coo import UGraph
from .rounds import RoundLedger, nbytes_of

UNKNOWN, IN, OUT = 0, 1, 2
BIGF = jnp.float32(jnp.inf)


def _mm_wave(estatus, u, v, erank, n, active_edge=None):
    """One fixpoint wave. Returns (new_estatus, resolved_now)."""
    m = erank.shape[0]
    unk = estatus == UNKNOWN
    # endpoints already matched by earlier waves — their edges can never join
    prev_in = estatus == IN
    pmatch = jnp.zeros((n,), jnp.int32)
    pmatch = pmatch.at[jnp.where(prev_in, u, n)].set(1, mode="drop")
    pmatch = pmatch.at[jnp.where(prev_in, v, n)].set(1, mode="drop")
    wbig = jnp.where(unk, erank, BIGF)
    vmin = jax.ops.segment_min(jnp.concatenate([wbig, wbig]),
                               jnp.concatenate([u, v]),
                               num_segments=n)
    is_min = (unk & (pmatch[u] == 0) & (pmatch[v] == 0)
              & (erank <= vmin[u]) & (erank <= vmin[v]))
    if active_edge is not None:
        is_min = is_min & active_edge
    new = jnp.where(is_min, IN, estatus)
    matched = pmatch
    matched = matched.at[jnp.where(is_min, u, n)].set(1, mode="drop")
    matched = matched.at[jnp.where(is_min, v, n)].set(1, mode="drop")
    die = (new == UNKNOWN) & ((matched[u] == 1) | (matched[v] == 1))
    if active_edge is not None:
        die = die & active_edge
    new = jnp.where(die, OUT, new)
    return new, matched


@functools.partial(jax.jit, static_argnames=("n",))
def _mm_fixpoint(u, v, erank, n: int, estatus0):
    """Run the LFMM fixpoint to completion inside one program."""
    def cond(s):
        estatus, it, q0, q1 = s
        return jnp.any(estatus == UNKNOWN)

    def body(s):
        estatus, it, q0, q1 = s
        new, _ = _mm_wave(estatus, u, v, erank, n)
        unk = estatus == UNKNOWN
        # queries: each unresolved edge probes both endpoint frontiers
        scanned = 2 * unk.sum()
        probe = jnp.zeros((n,), jnp.int32)
        probe = probe.at[jnp.where(unk, u, n)].set(1, mode="drop")
        probe = probe.at[jnp.where(unk, v, n)].set(1, mode="drop")
        return new, it + 1, q0 + scanned, q1 + probe.sum()

    estatus, iters, q0, q1 = jax.lax.while_loop(
        cond, body, (estatus0, jnp.int32(0), jnp.int32(0), jnp.int32(0)))
    return estatus, iters, q0, q1


def mm_ampc(g: UGraph, seed: int = 0,
            ledger: Optional[RoundLedger] = None,
            caching: bool = True) -> Tuple[np.ndarray, dict]:
    """Returns (in_mm bool(m,), stats)."""
    ledger = ledger if ledger is not None else RoundLedger("ampc_mm")
    n, m = g.n, g.m
    rng = np.random.default_rng(seed)
    erank = rng.permutation(m).astype(np.float32)

    with ledger.shuffle("SortEdges+WriteKV", nbytes_of(g.edges) * 2):
        u = jnp.asarray(g.edges[:, 0]); v = jnp.asarray(g.edges[:, 1])
        jrank = jnp.asarray(erank)

    with ledger.shuffle("IsInMM", m):
        estatus, iters, q0, q1 = _mm_fixpoint(
            u, v, jrank, n, jnp.zeros((m,), jnp.int32))
        estatus = np.asarray(jax.device_get(estatus))
        it = int(jax.device_get(iters))
        qn = int(jax.device_get(q0)); qd = int(jax.device_get(q1))
    queries = qd if caching else qn
    ledger.record_queries(queries, queries * 12, waves=it,
                          deduped_away=(qn - qd) if caching else 0)
    return estatus == IN, {"fixpoint_iters": it, "queries_nodedup": qn,
                           "queries_dedup": qd, "erank": erank}


def mm_ampc_levels(g: UGraph, seed: int = 0,
                   ledger: Optional[RoundLedger] = None) -> Tuple[np.ndarray, dict]:
    """Algorithm 4: O(log log Δ) geometric sampling levels."""
    ledger = ledger if ledger is not None else RoundLedger("ampc_mm_levels")
    n, m = g.n, g.m
    rng = np.random.default_rng(seed)
    erank01 = rng.permutation(m).astype(np.float64) / max(m, 1)  # π(e) in [0,1)
    delta = int(g.degrees().max()) if m else 1
    k = int(np.ceil(np.log2(max(np.log2(max(delta, 2)), 1.000001)))) + 1
    u = jnp.asarray(g.edges[:, 0]); v = jnp.asarray(g.edges[:, 1])
    jrank = jnp.asarray(erank01.astype(np.float32))
    estatus = jnp.zeros((m,), jnp.int32)
    level_stats = []
    ten_log_n = 10 * np.log(max(n, 2))
    for i in range(1, k + 1):
        # current degree of the residual graph
        unk = estatus == UNKNOWN
        deg = np.zeros(n, np.int64)
        eun = np.asarray(jax.device_get(unk))
        np.add.at(deg, g.edges[eun, 0], 1)
        np.add.at(deg, g.edges[eun, 1], 1)
        cur_delta = int(deg.max()) if eun.any() else 0
        if cur_delta == 0:
            break
        if cur_delta > ten_log_n:
            thresh = float(delta) ** (-(0.5 ** i))
        else:
            thresh = 1.1  # H_i = G_i
        in_h = jnp.asarray(erank01 <= thresh) & unk
        with ledger.shuffle(f"level_{i}_greedyMM", nbytes_of(g.edges)):
            # resolve the sampled subgraph completely (one AMPC launch)
            sub_status = jnp.where(in_h, UNKNOWN, OUT + 1)  # sentinel skip
            sub_status = jnp.where(in_h, jnp.int32(UNKNOWN), jnp.int32(3))
            st, iters, q0, q1 = _mm_fixpoint(
                u, v, jnp.where(in_h, jrank, BIGF), n,
                jnp.where(in_h, jnp.int32(UNKNOWN), jnp.int32(OUT)))
            # edges of H_i resolved; commit IN edges, kill touched vertices
            new_in = (st == IN) & in_h
            estatus = jnp.where(new_in, IN, estatus)
            matched = jnp.zeros((n,), jnp.int32)
            matched = matched.at[jnp.where(estatus == IN, u, n)].set(1, mode="drop")
            matched = matched.at[jnp.where(estatus == IN, v, n)].set(1, mode="drop")
            dead = (estatus == UNKNOWN) & ((matched[u] == 1) | (matched[v] == 1))
            estatus = jnp.where(dead, OUT, estatus)
            # H_i \ M_i edges whose endpoints survive go back to G_{i+1}:
            # (they were OUT in the sub-run only if endpoint matched — handled)
        level_stats.append({"level": i, "delta": cur_delta,
                            "threshold": thresh,
                            "iters": int(jax.device_get(iters))})
    st = np.asarray(jax.device_get(estatus))
    return st == IN, {"levels": level_stats, "k": k,
                      "erank": erank01.astype(np.float32)}


def mm_ampc_vertex_process(g: UGraph, epsilon: float = 0.5, seed: int = 0,
                           ledger: Optional[RoundLedger] = None,
                           ) -> Tuple[np.ndarray, dict]:
    """Theorem 2 part 2: vertex-started truncated query process.

    Each launch gives every vertex a fresh budget of n^ε queries; decisions on
    an edge are applied only while at least one endpoint still has budget, so
    resolution is delayed — never altered — and the output is the exact LFMM.
    """
    ledger = ledger if ledger is not None else RoundLedger("ampc_mm_vertex")
    n, m = g.n, g.m
    rng = np.random.default_rng(seed)
    erank = rng.permutation(m).astype(np.float32)
    u = jnp.asarray(g.edges[:, 0]); v = jnp.asarray(g.edges[:, 1])
    jrank = jnp.asarray(erank)
    budget = max(4, int(np.ceil(n ** epsilon)))

    @functools.partial(jax.jit, static_argnames=())
    def launch(estatus):
        qcount0 = jnp.zeros((n,), jnp.int32)

        def cond(s):
            estatus, qcount, it, q = s
            unk = estatus == UNKNOWN
            active = (qcount[u] < budget) | (qcount[v] < budget)
            return jnp.any(unk & active) & (it < 4 * budget)

        def body(s):
            estatus, qcount, it, q = s
            active = (qcount[u] < budget) | (qcount[v] < budget)
            new, _ = _mm_wave(estatus, u, v, jrank, n, active_edge=active)
            unk = estatus == UNKNOWN
            # each unresolved active edge costs one query at each live endpoint
            cost = jnp.zeros((n,), jnp.int32)
            live = unk & active
            cost = cost.at[jnp.where(live, u, n)].add(1, mode="drop")
            cost = cost.at[jnp.where(live, v, n)].add(1, mode="drop")
            return new, qcount + cost, it + 1, q + live.sum()

        return jax.lax.while_loop(cond, body,
                                  (estatus, qcount0, jnp.int32(0), jnp.int32(0)))

    estatus = jnp.zeros((m,), jnp.int32)
    launches, total_q = 0, 0
    while bool(jax.device_get(jnp.any(estatus == UNKNOWN))) and launches < 64:
        with ledger.shuffle(f"vertex_process_{launches}", m):
            estatus, qcount, iters, q = launch(estatus)
            total_q += int(jax.device_get(q))
        launches += 1
    ledger.record_queries(total_q, total_q * 12, waves=launches)
    st = np.asarray(jax.device_get(estatus))
    return st == IN, {"launches": launches, "budget": budget,
                      "queries": total_q, "erank": erank}


def mm_mpc_rootset(g: UGraph, seed: int = 0,
                   ledger: Optional[RoundLedger] = None,
                   max_phases: int = 500) -> Tuple[np.ndarray, dict]:
    ledger = ledger if ledger is not None else RoundLedger("mpc_mm")
    n, m = g.n, g.m
    rng = np.random.default_rng(seed)
    erank = rng.permutation(m).astype(np.float32)
    u = jnp.asarray(g.edges[:, 0]); v = jnp.asarray(g.edges[:, 1])
    jrank = jnp.asarray(erank)

    @jax.jit
    def phase(estatus):
        new, _ = _mm_wave(estatus, u, v, jrank, n)
        return new, (new == UNKNOWN).sum()

    estatus = jnp.zeros((m,), jnp.int32)
    phases, remaining = 0, m
    nb = nbytes_of(g.edges)
    while remaining > 0 and phases < max_phases:
        with ledger.shuffle(f"rootset_mark_{phases}", nb):
            estatus, rem = phase(estatus)
        with ledger.shuffle(f"rootset_remove_{phases}", nb):
            remaining = int(jax.device_get(rem))
        phases += 1
    st = np.asarray(jax.device_get(estatus))
    return st == IN, {"phases": phases, "erank": erank}
