"""Unified AMPC session API.

One entry point for every algorithm the paper studies::

    from repro.ampc import AmpcEngine
    res = AmpcEngine(dht_backend="routed").solve(g, "msf")
    results = AmpcEngine().solve_many(graphs, "mis")   # batched serving
    fut = AmpcEngine().submit(g, "mis")                # async serving
    sess = AmpcEngine().session(g)                     # snapshot reuse

See README.md in this directory for the engine / registry / backend design,
the batched ``solve_many`` path + compiled-solver cache, the async
``submit`` worker pool + ``GraphSession`` snapshot reuse, and the
deprecation path for the old per-module functions.
"""
from .async_engine import AmpcFuture
from .backends import DhtBackend, LocalDht, RoutedDht, resolve_backend
from .cache import CacheInfo, SolverCache
from .engine import AmpcEngine, AmpcResult, BatchSolveContext, SolveContext
from .registry import ProblemSpec, batched_impl, get as get_problem, \
    names as problem_names, problem, specs as problem_specs
from .session import GraphSession, GraphSnapshot, SNAPSHOT_PROBLEMS

__all__ = [
    "AmpcEngine", "AmpcResult", "SolveContext", "BatchSolveContext",
    "AmpcFuture", "GraphSession", "GraphSnapshot", "SNAPSHOT_PROBLEMS",
    "DhtBackend", "LocalDht", "RoutedDht", "resolve_backend",
    "CacheInfo", "SolverCache",
    "ProblemSpec", "problem", "batched_impl", "get_problem", "problem_names",
    "problem_specs",
]
