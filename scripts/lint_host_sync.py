#!/usr/bin/env python
"""Fail if a hot-path module re-introduces a host synchronization.

The deferred-accounting contract (docs/architecture.md, "Accounting model")
is that a warm solve's hot path is dispatch-only: every device->host
transfer is deferred into the single per-solve ``RoundLedger.harvest``.
That contract is easy to erode one innocent ``int(counter)`` at a time, so
this linter greps the hot-path modules for the synchronizing idioms JAX
offers and fails the check when one appears outside an explicit allowlist
comment.

Flagged idioms (substring match, per line):

  * ``device_get``        — jax.device_get blocks on the transfer
  * ``.item()``           — DeviceArray.item() is a transfer
  * ``int(jnp``           — int()/float() on a traced/device value syncs
  * ``float(jnp``
  * ``block_until_ready`` — an explicit barrier

A line that genuinely must sync (e.g. the eager-ledger compatibility path)
carries a ``# host-sync: ok`` comment with a short justification; the
linter skips those lines but still counts them, so the report shows how
many sanctioned syncs exist.

Usage: ``python scripts/lint_host_sync.py`` (repo root or anywhere).
Exit 0 when clean, 1 with a file:line report otherwise.
"""
from __future__ import annotations

import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent

# The modules a warm solve's per-round work flows through.  Solver driver
# loops (ampc/solvers.py eager fallbacks, mpc rootset simulators) keep
# genuine host control flow and are accounted for at the harvest instead.
HOT_PATH_MODULES = [
    "src/repro/core/dht.py",
    "src/repro/core/mis.py",
    "src/repro/core/matching.py",
    "src/repro/core/weighted_matching.py",
    "src/repro/core/connectivity.py",
    "src/repro/core/one_vs_two.py",
    "src/repro/core/msf.py",
    "src/repro/core/ternarize.py",
    "src/repro/ampc/backends.py",
    "src/repro/ampc/session.py",
]

SYNC_IDIOMS = [
    "device_get",
    ".item()",
    "int(jnp",
    "float(jnp",
    "block_until_ready",
]

ALLOW_MARK = "# host-sync: ok"


def lint_file(path: pathlib.Path):
    """Return (violations, allowed) lists of (lineno, line, idiom)."""
    violations, allowed = [], []
    for lineno, line in enumerate(path.read_text().splitlines(), 1):
        hit = next((idiom for idiom in SYNC_IDIOMS if idiom in line), None)
        if hit is None:
            continue
        (allowed if ALLOW_MARK in line else violations).append(
            (lineno, line.strip(), hit))
    return violations, allowed


def main(argv=None) -> int:
    failures = 0
    sanctioned = 0
    for rel in HOT_PATH_MODULES:
        path = REPO / rel
        if not path.exists():
            print(f"lint_host_sync: missing hot-path module {rel}",
                  file=sys.stderr)
            failures += 1
            continue
        violations, allowed = lint_file(path)
        sanctioned += len(allowed)
        for lineno, line, idiom in violations:
            print(f"{rel}:{lineno}: host sync `{idiom}` in hot path "
                  f"(annotate `{ALLOW_MARK} -- why` if intentional)\n"
                  f"    {line}", file=sys.stderr)
            failures += 1
    if failures:
        print(f"lint_host_sync: {failures} violation(s)", file=sys.stderr)
        return 1
    print(f"lint_host_sync: clean ({len(HOT_PATH_MODULES)} modules, "
          f"{sanctioned} sanctioned sync(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
