"""Distributed hash table (DHT) — the AMPC primitive, in JAX.

The paper's DHT stores the previous round's output as key-value pairs with
integer keys known to all machines.  On TPU the faithful realization is a
*device-sharded dense array* indexed by key: a lookup is a (collective)
gather.  Two execution paths:

  * ``lookup``        — plain ``jnp.take``; under pjit XLA partitions it into
                        the appropriate all-gather / gather-scatter pattern.
  * ``routed_lookup`` — explicit ``shard_map`` router: keys are deduped
                        ("caching", Section 5.3 of the paper), bucketed by
                        owner shard, exchanged with ``all_to_all``, answered
                        locally, and routed back.  This is the collective
                        schedule an RDMA KV store replaces, and it is what the
                        multi-pod dry-run exercises.

Both support the *caching optimization*: sort-dedup of the key batch before
fetching.  ``dedup_savings`` (queries avoided) is returned so benchmarks can
reproduce the paper's Figure 4 measurement.

The local path has two gather implementations (``ShardedDHT(impl=...)``):
``"take"`` (plain ``jnp.take`` after ``dedup_keys``) and ``"pallas"`` (the
``kernels.dht_gather`` cached-gather kernel, where the dedup happens as
VMEM row reuse and the hit count feeds the same ledger counters).  The
default is pallas on TPU and take elsewhere.

This module is **host-sync free** (enforced by ``scripts/lint_host_sync.py``):
every count a lookup produces is handed to the ledger as a raw device
scalar via ``RoundLedger.record_queries_deferred``; deferred ledgers queue
them and the engine harvests once per solve (see ``core.rounds``).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

INT_MAX = jnp.iinfo(jnp.int32).max


def dedup_keys(keys: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Sort-dedup a key batch (the paper's per-machine caching).

    Returns (uniq, inv, n_unique):
      uniq  — (K,) sorted unique keys first, INT_MAX padding after;
      inv   — (K,) position of each original key inside ``uniq``;
      n_unique — scalar count of distinct keys.
    Negative keys are treated as invalid (padding) and map to INT_MAX.
    """
    keys = jnp.asarray(keys, jnp.int32)
    safe = jnp.where(keys < 0, INT_MAX, keys)
    K = safe.shape[0]
    if K == 0:
        # zero-length batches appear once masked buckets land (an msf bucket
        # whose lane has no live queries); the group arithmetic below would
        # build a shape-(1,) newgrp for a shape-(0,) sort — guard explicitly
        return (jnp.full((0,), INT_MAX, jnp.int32),
                jnp.zeros((0,), jnp.int32), jnp.int32(0))
    # one argsort, then group arithmetic on the sorted view — replaces the
    # former sort + re-sort: `grp` numbers the distinct values in ascending
    # order, so scattering first-of-group values lands uniq already sorted,
    # and `grp` mapped back through `order` *is* the inverse index (invalid
    # keys share the INT_MAX group, whose index is exactly n_unique)
    order = jnp.argsort(safe).astype(jnp.int32)
    sk = jnp.take(safe, order)
    newgrp = jnp.concatenate([jnp.ones((1,), bool), sk[1:] != sk[:-1]])
    valid_first = newgrp & (sk != INT_MAX)
    n_unique = valid_first.sum()
    grp = (jnp.cumsum(newgrp) - 1).astype(jnp.int32)
    uniq = jnp.full((K,), INT_MAX, jnp.int32).at[
        jnp.where(valid_first, grp, K)].set(sk, mode="drop")
    inv = jnp.zeros((K,), jnp.int32).at[order].set(grp)
    return uniq, inv, n_unique


def lookup(values: jnp.ndarray, keys: jnp.ndarray, dedup: bool = True):
    """Gather ``values[keys]`` with optional dedup caching.

    Invalid (negative) keys return row 0 — callers mask them.
    Returns (gathered, n_unique_queries).
    """
    keys = jnp.asarray(keys, jnp.int32)
    if not dedup:
        safe = jnp.clip(keys, 0, values.shape[0] - 1)
        return jnp.take(values, safe, axis=0), jnp.asarray(keys.size, jnp.int32)
    uniq, inv, n_unique = dedup_keys(keys)
    safe = jnp.clip(jnp.where(uniq == INT_MAX, 0, uniq), 0, values.shape[0] - 1)
    fetched = jnp.take(values, safe, axis=0)
    return jnp.take(fetched, inv, axis=0), n_unique


def _fused_local_lookup(values, keys, row_bytes, dedup):
    """One-dispatch local path: the gather plus every counter the ledger
    records (queries, bytes, dedup savings) as a single compiled program.

    The op-by-op version paid ~10 host dispatches per lookup (argsort,
    cumsum, scatters, the counter arithmetic); fused, a warm lookup is one
    XLA launch and the staged counters ride along as extra outputs, so a
    deferred ledger never adds a dispatch of its own.
    """
    valid = (keys >= 0).sum()
    out, n_unique = lookup(values, keys, dedup=dedup)
    if not dedup:
        n_unique = valid
    nbytes = n_unique * (row_bytes + 4)
    deduped = (valid - n_unique) if dedup else jnp.int32(0)
    return out, n_unique, nbytes, deduped


_fused_local_lookup = jax.jit(_fused_local_lookup,
                              static_argnames=("dedup",))


def _owner(keys: jnp.ndarray, shard_size: int) -> jnp.ndarray:
    return jnp.where(keys == INT_MAX, INT_MAX, keys // shard_size)


def routed_lookup(values, keys, mesh, axis_name: str, capacity: int | None = None,
                  dedup: bool = True):
    """Explicit DHT router: dedup -> bucket by owner -> all_to_all -> answer
    -> all_to_all back -> un-dedup.

    ``values``: (n, ...) array sharded over ``axis_name`` (contiguous rows).
    ``keys``:   (Q,) int32, sharded over ``axis_name``; -1 = padding.
    ``capacity``: per-destination slots per device (static). Overflowing keys
    (beyond capacity for one owner) fall back to an unanswered marker; callers
    size capacity >= local Q for exactness (the default).
    Returns (gathered(Q, ...), n_unique, overflow_count).
    """
    n_shards = mesh.shape[axis_name]
    n = values.shape[0]
    assert n % n_shards == 0, "value rows must divide evenly across shards"
    shard_size = n // n_shards
    q_local = keys.shape[0] // n_shards
    cap = capacity or q_local

    def body(vals_l, keys_l):
        # vals_l: (shard_size, ...), keys_l: (q_local,)
        me = jax.lax.axis_index(axis_name)
        base = me * shard_size
        if dedup:
            uniq, inv, n_unique = dedup_keys(keys_l)
        else:
            uniq = jnp.where(keys_l < 0, INT_MAX, keys_l)
            inv = jnp.arange(q_local, dtype=jnp.int32)
            n_unique = (keys_l >= 0).sum()
        own = _owner(uniq, shard_size)
        order = jnp.argsort(own)
        sk = uniq[order]                       # keys sorted by owner
        so = _owner(sk, shard_size)
        # slot within destination bucket
        start = jnp.searchsorted(so, jnp.arange(n_shards, dtype=jnp.int32))
        slot = jnp.arange(sk.shape[0]) - jnp.take(start, jnp.clip(so, 0, n_shards - 1))
        valid = (sk != INT_MAX) & (slot < cap)
        overflow = ((sk != INT_MAX) & (slot >= cap)).sum()
        # scatter into (n_shards, cap) send buffer
        flat_pos = jnp.where(valid, so * cap + slot, n_shards * cap)
        send = jnp.full((n_shards * cap + 1,), INT_MAX, jnp.int32)
        send = send.at[flat_pos].set(jnp.where(valid, sk, INT_MAX))
        send = send[:-1].reshape(n_shards, cap)
        # exchange keys: row d of `recv` = keys sent to me by device d
        recv = jax.lax.all_to_all(send, axis_name, 0, 0, tiled=False)
        # answer locally
        rk = recv.reshape(-1)
        local_idx = jnp.clip(jnp.where(rk == INT_MAX, 0, rk - base), 0, shard_size - 1)
        ans = jnp.take(vals_l, local_idx, axis=0)
        ans = jnp.where((rk == INT_MAX)[(...,) + (None,) * (ans.ndim - 1)], 0, ans)
        ans = ans.reshape((n_shards, cap) + ans.shape[1:])
        # route answers back
        back = jax.lax.all_to_all(ans, axis_name, 0, 0, tiled=False)
        back = back.reshape((n_shards * cap,) + back.shape[2:])
        # un-permute: sorted-by-owner position -> uniq position -> original
        uniq_vals = jnp.zeros((sk.shape[0],) + back.shape[1:], back.dtype)
        src = jnp.take(back, jnp.where(valid, flat_pos, 0), axis=0)
        uniq_vals = uniq_vals.at[order].set(
            jnp.where(valid[(...,) + (None,) * (src.ndim - 1)], src, 0))
        out = jnp.take(uniq_vals, inv, axis=0)
        return out, n_unique[None], overflow[None]

    spec_v = P(axis_name) if values.ndim == 1 else P(axis_name, *([None] * (values.ndim - 1)))
    out_spec = P(axis_name) if values.ndim == 1 else P(axis_name, *([None] * (values.ndim - 1)))
    fn = shard_map(body, mesh=mesh,
                   in_specs=(spec_v, P(axis_name)),
                   out_specs=(out_spec, P(axis_name), P(axis_name)),
                   check_rep=False)
    out, n_unique, overflow = fn(values, keys)
    return out, n_unique.sum(), overflow.sum()


class ShardedDHT:
    """Host-level DHT snapshot with uniform ledger accounting.

    Without a ``mesh`` every lookup takes the local gather path
    (``lookup``); with a ``mesh`` it takes the explicit all_to_all router
    (``routed_lookup``).  Both paths report query / byte / dedup / overflow
    counters through the *same* ledger calls, so AMPC accounting is
    backend-independent (the paper's DHT abstraction).
    """

    def __init__(self, values: jnp.ndarray, ledger=None,
                 value_bytes: int | None = None, mesh=None,
                 axis_name: str = "dht", capacity: int | None = None,
                 impl: str | None = None):
        self.values = values
        self.ledger = ledger
        self.mesh = mesh
        self.axis_name = axis_name
        self.capacity = capacity
        self._row_bytes = value_bytes or int(
            values.dtype.itemsize * (values.size // max(values.shape[0], 1)))
        if impl is None:
            # the cached-gather kernel is compiled on TPU; elsewhere it
            # would run under the Pallas interpreter, so default to take
            impl = "pallas" if jax.default_backend() == "tpu" else "take"
        if impl not in ("take", "pallas"):
            raise ValueError(f"impl must be 'take' or 'pallas', got {impl!r}")
        self.impl = impl
        # routed path: pad value rows to the shard grid once per snapshot
        # (a snapshot is immutable, so re-padding per lookup was pure waste)
        if mesh is not None:
            n_shards = mesh.shape[self.axis_name]
            pad_rows = (-values.shape[0]) % n_shards
            if pad_rows:
                fill = jnp.zeros((pad_rows,) + values.shape[1:], values.dtype)
                self._padded_values = jnp.concatenate([values, fill])
            else:
                self._padded_values = values

    @property
    def backend(self) -> str:
        return "local" if self.mesh is None else "routed"

    def _routed(self, keys, dedup: bool):
        """Pad keys to the shard grid, route, then slice back."""
        n_shards = self.mesh.shape[self.axis_name]
        q = int(keys.size)
        pad_q = (-q) % n_shards
        k = keys
        if pad_q:
            k = jnp.concatenate([k, jnp.full((pad_q,), -1, jnp.int32)])
        out, n_unique, overflow = routed_lookup(
            self._padded_values, k, self.mesh, self.axis_name,
            capacity=self.capacity, dedup=dedup)
        if pad_q:
            out = out[:q]
        return out, n_unique, overflow

    def _pallas_gather(self, keys):
        """Cached-gather kernel path: returns (out, cache_hits).

        The kernel's hit count satisfies ``hits == valid - distinct``
        (cross-block carry in the kernel), so the caller derives
        ``n_unique = valid - hits`` — bit-identical to ``dedup_keys``.
        Invalid keys are re-pointed at row 0 afterwards to match the
        take path's output contract exactly.
        """
        from ..kernels.dht_gather.ops import dht_gather

        values = self.values
        table = values.reshape(values.shape[0], -1)
        out, hits = dht_gather(table, jnp.where(keys < 0, -1, keys),
                               impl="pallas")
        out = out.reshape(keys.shape + values.shape[1:])
        out = jnp.where((keys < 0)[(...,) + (None,) * (values.ndim - 1)],
                        values[0], out)
        return out, hits

    def lookup(self, keys, dedup: bool = True):
        keys = jnp.asarray(keys, jnp.int32)
        tracer = getattr(self.ledger, "tracer", None)
        if tracer is not None and tracer.enabled:
            with tracer.span("dht:lookup", backend=self.backend,
                             keys=int(keys.size), dedup=dedup):
                return self._lookup(keys, dedup)
        return self._lookup(keys, dedup)

    def _lookup(self, keys, dedup: bool):
        # negative keys are padding: they are never queried, so they count
        # neither as queries nor as dedup savings, on either backend.
        # Every count below stays on the device: the ledger decides when
        # (or whether) to sync — deferred ledgers harvest once per solve.
        ledger = self.ledger
        if keys.size == 0:
            # zero-length query batch: nothing to exchange on any backend or
            # impl, and the routed router cannot even pad an empty batch onto
            # the shard grid — answer locally with an empty gather and record
            # explicit zero counters (plain host ints work on both eager and
            # deferred ledgers without adding a sync)
            if ledger is not None:
                ledger.record_queries(0, 0, waves=0)
            return jnp.zeros(keys.shape + self.values.shape[1:],
                             self.values.dtype)
        eager = ledger is not None and not getattr(ledger, "deferred", False)
        if self.mesh is None:
            if dedup and self.impl == "pallas" and keys.size and \
                    self.values.size:
                valid = (keys >= 0).sum()
                out, hits = self._pallas_gather(keys)
                n_unique = valid - hits
                nbytes = n_unique * (self._row_bytes + 4)
                deduped = hits
            elif eager:
                # Seed-faithful eager hot path, preserved verbatim for
                # deferred=False ledgers: the immediate-readability
                # contract forces one blocking sync before the gather
                # dispatch (valid) and one after it (n_unique) — exactly
                # the per-lookup stalls the deferred ledger removes.
                valid = int(jax.device_get((keys >= 0).sum()))  # host-sync: ok -- eager ledger contract
                out, n_unique = lookup(self.values, keys, dedup=dedup)
                nu = valid if not dedup \
                    else int(jax.device_get(n_unique))  # host-sync: ok -- eager ledger contract
                ledger.record_queries(
                    nu, nu * (self._row_bytes + 4), waves=1,
                    deduped_away=(valid - nu) if dedup else 0)
                return out
            else:
                out, n_unique, nbytes, deduped = _fused_local_lookup(
                    self.values, keys, self._row_bytes, dedup)
            overflow = 0
        else:
            valid = (keys >= 0).sum()
            out, n_unique, overflow = self._routed(keys, dedup)
            nbytes = n_unique * (self._row_bytes + 4)
            deduped = (valid - n_unique) if dedup else 0
        if self.ledger is not None:
            self.ledger.record_queries_deferred(
                n_unique, nbytes, waves=1, deduped_away=deduped,
                overflow=overflow)
        return out
