"""jit wrapper with impl switch for flash attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import flash_attention_fwd
from .ref import attention_ref


def flash_attention(q, k, v, causal: bool = True, window=0,
                    impl: str = "pallas", interpret: bool = True,
                    block_q: int = 128, block_kv: int = 128):
    """Dispatch: "pallas" (TPU kernel; interpret=True on CPU) or "xla" (ref).
    ``window`` must be a static int for the pallas path (kernel specializes
    the mask); traced windows fall back to the reference path."""
    if impl == "pallas" and isinstance(window, (int, type(None))):
        w = int(window or 0)
        return flash_attention_fwd(q, k, v, causal=causal, window=w,
                                   block_q=block_q, block_kv=block_kv,
                                   interpret=interpret)
    return attention_ref(q, k, v, causal=causal,
                         window=int(window) if isinstance(window, int) else 0)
