"""qwen2.5-32b: 64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064,
GQA + QKV bias."""
from .lm_archs import QWEN2_5_32B as CONFIG, smoke
SMOKE = smoke(CONFIG)
