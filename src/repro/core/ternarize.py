"""Ternarization (Algorithm 2, line 2): bound degrees by 3.

Every vertex v with deg(v) > 3 is replaced by a cycle of deg(v) dummy
vertices; the i-th incident edge of v attaches to the i-th cycle vertex.
Dummy cycle edges get weight "bottom" (strictly below the lightest real edge)
so they always enter the MSF first and never displace real MSF edges; they are
removed from the output (their edge id is -1).

Host-side numpy — this is a data-layout transformation, part of the input
pipeline of the MSF job.

``ternarize_batch`` is the bucketable variant used by the ``solve_many``
batch adapters: it ternarizes every graph of a shape bucket and pads the
results to shared pow-2 ``(nt_bucket, mt_bucket)`` shapes with masked lanes,
following the same padding conventions as ``repro.graph.batching`` (isolated
padded vertices, ``+inf`` padded weights, ``-1`` padded ids) so a vmapped
truncated-Prim / contract / Borůvka pipeline is bit-identical per lane to
the sequential one.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

from ..graph.batching import next_pow2
from ..graph.coo import UGraph


@dataclasses.dataclass
class TernGraph:
    g: UGraph                 # ternarized graph (weights include dummy edges)
    orig_eid: np.ndarray      # (m_tern,) original edge id, -1 for dummy edges
    node_of: np.ndarray       # (n_tern,) original vertex of each tern vertex
    n_orig: int
    m_orig: int


def ternarize(g: UGraph) -> TernGraph:
    assert g.weights is not None, "ternarize expects a weighted graph"
    n, m = g.n, g.m
    deg = g.degrees()
    slots = np.maximum(deg, 1)
    expand = deg > 3
    n_slots = np.where(expand, slots, 1).astype(np.int64)
    offset = np.zeros(n + 1, np.int64)
    np.cumsum(n_slots, out=offset[1:])
    n_tern = int(offset[-1])

    # position of each directed edge inside its source's adjacency list
    indptr, indices, w, eid = g.csr()
    pos_in_adj = np.arange(len(indices), dtype=np.int64) - np.repeat(indptr[:-1], np.diff(indptr))
    src = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    # per undirected edge, slot at each endpoint
    slot_u = np.zeros(m, np.int64)
    slot_v = np.zeros(m, np.int64)
    # each undirected eid appears exactly twice in the directed view
    first_seen = np.full(m, -1, np.int64)
    for p in range(len(indices)):
        e = eid[p]
        if first_seen[e] < 0:
            first_seen[e] = p
            slot_u[e] = pos_in_adj[p]
        else:
            slot_v[e] = pos_in_adj[p]
    del src

    u, v = g.edges[:, 0].astype(np.int64), g.edges[:, 1].astype(np.int64)
    nu = offset[u] + np.where(expand[u], slot_u, 0)
    nv = offset[v] + np.where(expand[v], slot_v, 0)
    real_edges = np.stack([nu, nv], axis=1)

    # dummy cycle edges for expanded vertices
    exp_ids = np.where(expand)[0]
    dummy_u, dummy_v = [], []
    for x in exp_ids:
        base, d = offset[x], deg[x]
        idx = base + np.arange(d)
        dummy_u.append(idx)
        dummy_v.append(base + (np.arange(d) + 1) % d)
    if dummy_u:
        dummy_edges = np.stack([np.concatenate(dummy_u), np.concatenate(dummy_v)], axis=1)
    else:
        dummy_edges = np.zeros((0, 2), np.int64)

    lightest = float(g.weights.min()) if m else 0.0
    bot = lightest - 1.0
    k = dummy_edges.shape[0]
    dummy_w = bot - np.arange(k, dtype=np.float32) / max(k, 1)  # distinct, all < lightest

    edges = np.concatenate([real_edges, dummy_edges]).astype(np.int32)
    weights = np.concatenate([g.weights, dummy_w]).astype(np.float32)
    orig = np.concatenate([np.arange(m, dtype=np.int32), np.full(k, -1, np.int32)])

    node_of = np.repeat(np.arange(n, dtype=np.int32), n_slots)
    tg = UGraph(n_tern, edges, weights)
    return TernGraph(tg, orig, node_of, n, m)


@dataclasses.dataclass
class TernBatch:
    """One shape bucket of ternarized graphs, padded and stacked.

    Padding conventions (mirroring ``repro.graph.batching``):

      * ``nbr``/``nbe`` pad with ``-1`` and ``nbw`` with ``+inf`` — a padded
        tern vertex looks exhausted to truncated Prim on its first frontier
        pop (1 query, case 2), which the adapters mask out of ``q_sum``;
      * ``edges`` pad with ``(0, 0)`` and ``edge_mask`` False, so the
        contraction invalidates them before they can join a component;
      * ``orig_eid`` pads with ``-1`` (indistinguishable from dummy cycle
        edges, which are filtered the same way);
      * real tern vertices / edges occupy the prefix of every row, so
        per-lane slices ``[:n_tern[b]]`` / ``[:m_tern[b]]`` recover the
        sequential arrays exactly.
    """

    terns: List[TernGraph]   # per-graph host ternarizations (orig_eid maps)
    nt_bucket: int
    mt_bucket: int
    n_tern: np.ndarray       # (B,) int64 real tern vertex counts
    m_tern: np.ndarray       # (B,) int64 real tern edge counts
    nbr: np.ndarray          # (B, nt_bucket, 3) int32, -1 pad
    nbw: np.ndarray          # (B, nt_bucket, 3) f32, +inf pad
    nbe: np.ndarray          # (B, nt_bucket, 3) int32, -1 pad
    edges: np.ndarray        # (B, mt_bucket, 2) int32, (0, 0) pad
    weights: np.ndarray      # (B, mt_bucket) f32, +inf pad
    orig_eid: np.ndarray     # (B, mt_bucket) int32, -1 pad
    edge_mask: np.ndarray    # (B, mt_bucket) bool
    node_mask: np.ndarray    # (B, nt_bucket) bool

    def __len__(self) -> int:
        return len(self.terns)


def ternarize_batch(graphs: Sequence[UGraph]) -> TernBatch:
    """Ternarize a bucket of graphs into one padded :class:`TernBatch`.

    The bucket shape is the next power of two over the largest ternarized
    vertex/edge count in the batch, so one compiled vmapped solver serves
    every occupant (and recurs across fleets whose ternarizations land in
    the same bucket)."""
    terns = [ternarize(g) for g in graphs]
    B = len(terns)
    nts = np.array([t.g.n for t in terns], np.int64)
    mts = np.array([t.g.m for t in terns], np.int64)
    ntb = next_pow2(int(nts.max()) if B else 1)
    mtb = next_pow2(int(mts.max()) if B else 1)
    nbr = np.full((B, ntb, 3), -1, np.int32)
    nbw = np.full((B, ntb, 3), np.inf, np.float32)
    nbe = np.full((B, ntb, 3), -1, np.int32)
    edges = np.zeros((B, mtb, 2), np.int32)
    weights = np.full((B, mtb), np.inf, np.float32)
    orig_eid = np.full((B, mtb), -1, np.int32)
    edge_mask = np.zeros((B, mtb), bool)
    node_mask = np.zeros((B, ntb), bool)
    for b, t in enumerate(terns):
        nt, mt = t.g.n, t.g.m
        bn, bw, be = t.g.padded_adj(3)
        nbr[b, :nt] = bn
        nbw[b, :nt] = bw
        nbe[b, :nt] = be
        edges[b, :mt] = t.g.edges
        weights[b, :mt] = t.g.weights
        orig_eid[b, :mt] = t.orig_eid
        edge_mask[b, :mt] = True
        node_mask[b, :nt] = True
    return TernBatch(terns=terns, nt_bucket=ntb, mt_bucket=mtb,
                     n_tern=nts, m_tern=mts, nbr=nbr, nbw=nbw, nbe=nbe,
                     edges=edges, weights=weights, orig_eid=orig_eid,
                     edge_mask=edge_mask, node_mask=node_mask)
