"""Benchmark registry — mirrors ``repro.ampc.registry`` for the harness.

Each benchmark module decorates its ``run`` with ``@bench(...)``; the
harness (``benchmarks.run``) dispatches by registry lookup instead of
``__import__`` + ad-hoc kwargs, and applies the shared ``--graphs`` /
``--quick`` config path uniformly.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional


@dataclasses.dataclass(frozen=True)
class BenchSpec:
    name: str
    fn: Callable                      # run(**kwargs) -> result dict
    takes_graphs: bool = False        # accepts graph_names=[...]
    quick_kwargs: dict = dataclasses.field(default_factory=dict)
    summary: str = ""


REGISTRY: Dict[str, BenchSpec] = {}


def bench(name: str, *, takes_graphs: bool = False,
          quick_kwargs: Optional[dict] = None, summary: str = ""):
    """Register a benchmark entry point."""

    def deco(fn):
        if name in REGISTRY:
            raise ValueError(f"duplicate benchmark registration: {name}")
        REGISTRY[name] = BenchSpec(name=name, fn=fn,
                                   takes_graphs=takes_graphs,
                                   quick_kwargs=dict(quick_kwargs or {}),
                                   summary=summary)
        return fn

    return deco


def load_all():
    """Import every benchmark module so decorators run; returns REGISTRY."""
    from . import (table3_rounds, bytes_comm, mis_caching, runtimes,  # noqa
                   msf_queries, solve_many, dht_hot_path,             # noqa
                   gnn_dht_hillclimb, profile_cell, roofline)         # noqa
    return REGISTRY


def get(name: str) -> BenchSpec:
    load_all()
    if name not in REGISTRY:
        raise KeyError(f"unknown benchmark {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def names():
    # insertion (curated) order: headline tables first, roofline last
    return list(load_all())
