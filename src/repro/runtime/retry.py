"""Resilient execution of jitted programs.

Two concerns are handled here:

1. **Runtime-level retry** (fault tolerance): a launch that fails with a
   transient runtime error is retried after invalidating the executable
   cache — the same recovery path a production runner takes after losing a
   worker mid-step (recompile + re-execute from the last materialized
   round).  This also works around an XLA-CPU executable re-execution bug
   observed in this environment ("Execution supplied N buffers but compiled
   program expected M buffers" on a warm-cache second execution), which we
   treat exactly like a lost executable.

2. **Bounded retries**: repeated failure surfaces the original error.
"""
from __future__ import annotations

import logging
from typing import Any, Callable

import jax

log = logging.getLogger(__name__)

_TRANSIENT_MARKERS = (
    "buffers but compiled program expected",   # XLA CPU re-execution bug
    "RESOURCE_EXHAUSTED",
    "preempted",
)


def is_transient(err: Exception) -> bool:
    msg = str(err)
    return any(marker in msg for marker in _TRANSIENT_MARKERS)


def resilient_call(fn: Callable, *args, _retries: int = 2, **kwargs) -> Any:
    """Call ``fn`` (usually a jitted function); on a transient runtime
    failure, drop cached executables and retry (recompiles)."""
    attempt = 0
    while True:
        try:
            return fn(*args, **kwargs)
        except ValueError as e:  # jaxlib surfaces XLA runtime errors as ValueError
            if attempt >= _retries or not is_transient(e):
                raise
            attempt += 1
            log.warning("transient launch failure (%s); clearing caches and "
                        "retrying (%d/%d)", e, attempt, _retries)
            try:
                if hasattr(fn, "clear_cache"):
                    fn.clear_cache()
                else:
                    jax.clear_caches()
            except Exception:  # pragma: no cover - best effort
                jax.clear_caches()
