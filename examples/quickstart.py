"""Quickstart: the paper's four algorithms on a social-network-like graph,
with the AMPC-vs-MPC round/byte accounting (Table 3 in miniature).

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.graph import generators as gen
from repro.core import connectivity as cc, matching as mm, mis, msf, \
    one_vs_two as ovt, oracle
from repro.core.rounds import RoundLedger


def main():
    g = gen.rmat(12, 8.0, seed=0)
    print(f"graph: n={g.n} m={g.m} (RMAT, power-law)")

    # --- MIS
    la, lm = RoundLedger("ampc"), RoundLedger("mpc")
    s_a, st = mis.mis_ampc(g, seed=0, ledger=la)
    s_m, _ = mis.mis_mpc_rootset(g, seed=0, ledger=lm)
    assert np.array_equal(s_a, s_m), "same randomness => same MIS"
    print(f"\nMIS: |I|={s_a.sum()}  AMPC shuffles={la.shuffles} "
          f"(cache saved {st['cache_savings_factor']:.1f}x queries)  "
          f"MPC shuffles={lm.shuffles}")

    # --- Maximal matching
    la, lm = RoundLedger("ampc"), RoundLedger("mpc")
    m_a, st = mm.mm_ampc(g, seed=0, ledger=la)
    print(f"MM : |M|={m_a.sum()}  AMPC shuffles={la.shuffles}  "
          f"maximal={oracle.is_maximal_matching(g, m_a)}")

    # --- MSF (degree weights, Section 5.2)
    gw = g.with_degree_weights()
    la, lm = RoundLedger("ampc"), RoundLedger("mpc")
    f_a, st = msf.msf_ampc(gw, seed=0, ledger=la,
                           skip_ternarize_if_dense=False)
    f_m, stm = msf.msf_mpc_boruvka(gw, seed=0, ledger=lm)
    print(f"MSF: weight={gw.weights[f_a].sum():.0f}  AMPC shuffles="
          f"{la.shuffles} (queries/vertex={st['avg_queries_per_vertex']:.1f})"
          f"  MPC shuffles={lm.shuffles} ({stm['phases']} Borůvka phases)")

    # --- 1-vs-2 cycle
    for name, cyc, expect in [("one", gen.one_cycle(20000), 1),
                              ("two", gen.two_cycles(10000), 2)]:
        la = RoundLedger("ampc")
        n_a, st = ovt.one_vs_two_ampc(cyc, p=1 / 64, seed=0, ledger=la)
        n_m, stm = ovt.one_vs_two_mpc(cyc, seed=0)
        print(f"1v2c({name}): AMPC says {n_a} in {la.shuffles} shuffles; "
              f"MPC says {n_m} in {3 * stm['phases']} shuffles")
        assert n_a == n_m == expect

    # --- connectivity
    parts = gen.disjoint_components([3000, 2000, 1000], 4.0, seed=1)
    labels, st = cc.cc_ampc(parts, seed=0)
    print(f"CC : {st['num_components']} components (expected 3)")


if __name__ == "__main__":
    main()
