"""gin-tu: 5 layers, d_hidden=64, sum aggregator, learnable eps."""
from ..models.gnn.gin import GINConfig
CONFIG = GINConfig()
SMOKE = GINConfig(d_hidden=16)
