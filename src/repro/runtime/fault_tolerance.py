"""Fault-tolerant training runner + straggler-aware work dispatch.

The AMPC paper's environment (Section 5.1) runs batch jobs at low priority
where preemption is the norm; durability comes from materializing every
round.  This runner provides the analog for the training/serving side:

  * step-level checkpoints (atomic, keep-N) with resume-from-latest;
  * a preemption simulator (tests kill the runner mid-run and restart it);
  * deterministic data: batch(step) is a pure function of (seed, step), so
    restart needs no data-state, and any worker can regenerate any shard;
  * straggler mitigation at the dispatch level: the global batch is
    over-decomposed into work chunks; chunks owned by a worker that misses
    its deadline are re-issued to idle workers (at-least-once execution with
    idempotent chunk ids; the consumer dedups by chunk id).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Set

from ..checkpoint import checkpointer as ckpt


@dataclasses.dataclass
class RunnerConfig:
    ckpt_dir: str
    ckpt_every: int = 10
    keep: int = 3
    max_steps: int = 100


class TrainRunner:
    """Drives (state, step) -> state with checkpoint/restart."""

    def __init__(self, cfg: RunnerConfig, init_state_fn: Callable[[], dict],
                 step_fn: Callable[[dict, int], dict],
                 shardings=None):
        self.cfg = cfg
        self.init_state_fn = init_state_fn
        self.step_fn = step_fn
        self.shardings = shardings

    def run(self, crash_at_step: Optional[int] = None) -> dict:
        state = self.init_state_fn()
        start = 0
        if ckpt.latest_step(self.cfg.ckpt_dir) is not None:
            state, start = ckpt.restore(self.cfg.ckpt_dir, state,
                                        shardings=self.shardings)
            start += 1
        for step in range(start, self.cfg.max_steps):
            if crash_at_step is not None and step == crash_at_step:
                raise RuntimeError(f"simulated preemption at step {step}")
            state = self.step_fn(state, step)
            if (step + 1) % self.cfg.ckpt_every == 0 or \
                    step == self.cfg.max_steps - 1:
                ckpt.save(self.cfg.ckpt_dir, step, state, keep=self.cfg.keep)
        return state


# --------------------------------------------------------------------------
# Straggler-aware chunk dispatch (host-side scheduling model)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Chunk:
    chunk_id: int
    owner: int
    issued_at: float
    done: bool = False


class StragglerDispatcher:
    """Over-decomposed work assignment with deadline-based re-issue.

    ``n_chunks`` should be a small multiple of ``n_workers`` (the paper's
    balls-into-bins argument, Lemma 8.4 of [19], bounds per-machine load).
    Chunks are idempotent: duplicated execution is deduped by chunk id.
    """

    def __init__(self, n_chunks: int, n_workers: int, deadline_s: float):
        self.n_workers = n_workers
        self.deadline = deadline_s
        self.pending: List[int] = list(range(n_chunks))
        self.inflight: Dict[int, Chunk] = {}
        self.completed: Set[int] = set()
        self.reissues = 0

    def assign(self, worker: int, now: Optional[float] = None) -> Optional[int]:
        now = time.monotonic() if now is None else now
        # re-issue chunks whose owner blew the deadline (straggler)
        for c in list(self.inflight.values()):
            if not c.done and now - c.issued_at > self.deadline:
                del self.inflight[c.chunk_id]
                self.pending.append(c.chunk_id)
                self.reissues += 1
        if not self.pending:
            return None
        cid = self.pending.pop(0)
        self.inflight[cid] = Chunk(cid, worker, now)
        return cid

    def complete(self, chunk_id: int) -> bool:
        """Returns True if this completion is the first (not a dup)."""
        first = chunk_id not in self.completed
        self.completed.add(chunk_id)
        self.inflight.pop(chunk_id, None)
        return first

    @property
    def all_done(self) -> bool:
        return not self.pending and all(
            c.chunk_id in self.completed for c in self.inflight.values()) \
            and len(self.completed) > 0
