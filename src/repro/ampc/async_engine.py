"""Async solve futures: ``engine.submit(graph, problem) -> AmpcFuture``.

The serving loop around a synchronous :class:`~repro.ampc.engine.AmpcEngine`
must block on every solve even though most of a solve's wall time on the
host side — validation, ledger assembly, rank drawing, output collection,
span bookkeeping — is independent work between solves.  This module adds a
bounded worker pool behind the engine so independent solves overlap those
host-side phases while **device launches stay serialized** through one
engine-wide launch lock (``AmpcEngine(serialize_launches=...)``): the AMPC
accounting model, where a launch is a materialized round, keeps exactly one
program in flight per engine.

Surface (mixed into ``AmpcEngine``):

  * ``submit(graph, problem, ...) -> AmpcFuture`` — enqueue one solve.
    Bounded queue: when ``queue_depth`` solves are already waiting, submit
    **blocks** (backpressure) until a worker drains one.
  * ``submit_many(graphs, problem, ...) -> [AmpcFuture, ...]``.
  * ``shutdown(drain=True)`` — stop accepting work; drain or cancel the
    queue; join the workers.  Idempotent; also the engine's context-manager
    exit.

Every future is observable end to end: the worker wraps the solve in a
``solve[async]`` span (the pool-queue wait is recorded as a ``queue_wait``
event on it), transient launch failures retried by
:func:`repro.runtime.retry.resilient_call` attach their WARN
``transient_retry`` events to that same span — the *owning* future's — and
the pool reports ``engine_async_submitted_total`` /
``engine_async_cancelled_total`` counters plus the ``engine_async_inflight``
gauge (back to 0 whenever the pool is idle).

A future resolves with the same :class:`AmpcResult` a sequential
``engine.solve`` call returns — bit-identical outputs, its own per-solve
``RoundLedger`` — plus ``stats["async"]`` carrying the queue wait and
worker attribution.

Deferred accounting matters most here: each worker's solve performs exactly
one ``jax.device_get`` harvest at result-materialization time, so a solve
holding the launch lock never stalls the pipeline on per-lookup counter
syncs — the next queued solve's host-side phases overlap with the previous
solve's device work all the way up to its single harvest.
"""
from __future__ import annotations

import itertools
import queue
import threading
import time
from concurrent.futures import CancelledError, TimeoutError as FutureTimeout
from typing import Any, List, Optional, Sequence

from ..runtime.retry import resilient_call

__all__ = ["AmpcFuture", "AsyncEngineMixin", "CancelledError",
           "FutureTimeout"]

# future states
_PENDING = "PENDING"
_RUNNING = "RUNNING"
_DONE = "DONE"
_CANCELLED = "CANCELLED"

_STOP = object()          # worker sentinel
_ids = itertools.count(1)


class AmpcFuture:
    """Handle to one queued/running async solve.

    Mirrors the ``concurrent.futures.Future`` surface (``result`` /
    ``exception`` / ``cancel`` / ``done`` / ``cancelled`` / ``running``)
    with AMPC-specific metadata: the problem name, a process-unique
    ``future_id`` (the ``future`` attribute of its ``solve[async]`` span),
    and an optional deadline after which a still-queued solve fails with
    ``TimeoutError`` instead of starting.

    A running solve cannot be interrupted (it is one jitted launch);
    ``cancel()`` succeeds only while the future is still queued.
    """

    def __init__(self, graph, problem: str, opts: dict,
                 deadline: Optional[float] = None, retries: int = 2):
        self.graph = graph
        self.problem = problem
        self.opts = opts
        self.deadline = deadline
        self.retries = retries
        self.future_id = next(_ids)
        self.span = None                      # solve[async] span when traced
        self._cond = threading.Condition()
        self._state = _PENDING
        self._result = None
        self._exc: Optional[BaseException] = None
        self._enqueued_at = time.monotonic()
        self._on_terminal = None              # engine callback, fired once

    # -- inspection --------------------------------------------------------
    def done(self) -> bool:
        with self._cond:
            return self._state in (_DONE, _CANCELLED)

    def cancelled(self) -> bool:
        with self._cond:
            return self._state == _CANCELLED

    def running(self) -> bool:
        with self._cond:
            return self._state == _RUNNING

    # -- consumer side -----------------------------------------------------
    def result(self, timeout: Optional[float] = None):
        """Block until resolved; return the ``AmpcResult``.

        Raises ``CancelledError`` if the future was cancelled, re-raises
        the solve's exception if it failed, and raises
        ``concurrent.futures.TimeoutError`` if ``timeout`` elapses first.
        """
        with self._cond:
            if not self._cond.wait_for(
                    lambda: self._state in (_DONE, _CANCELLED), timeout):
                raise FutureTimeout(
                    f"future {self.future_id} ({self.problem}) unresolved "
                    f"after {timeout}s")
            if self._state == _CANCELLED:
                raise CancelledError(
                    f"future {self.future_id} ({self.problem}) was cancelled")
            if self._exc is not None:
                raise self._exc
            return self._result

    def exception(self, timeout: Optional[float] = None):
        """The exception the solve raised (None on success); blocks like
        ``result``.  Raises ``CancelledError`` for cancelled futures."""
        with self._cond:
            if not self._cond.wait_for(
                    lambda: self._state in (_DONE, _CANCELLED), timeout):
                raise FutureTimeout(
                    f"future {self.future_id} ({self.problem}) unresolved "
                    f"after {timeout}s")
            if self._state == _CANCELLED:
                raise CancelledError(
                    f"future {self.future_id} ({self.problem}) was cancelled")
            return self._exc

    def cancel(self) -> bool:
        """Cancel if still queued.  Returns True on success; False once the
        solve is running or resolved (it cannot be interrupted)."""
        with self._cond:
            if self._state != _PENDING:
                return False
            self._state = _CANCELLED
            self._cond.notify_all()
        self._fire_terminal()
        return True

    # -- worker side -------------------------------------------------------
    def _try_start(self) -> bool:
        with self._cond:
            if self._state != _PENDING:
                return False
            self._state = _RUNNING
            return True

    def _finish(self, result=None, exc: Optional[BaseException] = None):
        with self._cond:
            self._result = result
            self._exc = exc
            self._state = _DONE
            self._cond.notify_all()
        self._fire_terminal()

    def _fire_terminal(self):
        cb, self._on_terminal = self._on_terminal, None
        if cb is not None:
            cb(self)

    def __repr__(self):
        with self._cond:
            return (f"AmpcFuture(id={self.future_id}, "
                    f"problem={self.problem!r}, state={self._state})")


class AsyncEngineMixin:
    """``submit``/``submit_many``/``shutdown`` for :class:`AmpcEngine`.

    The host class provides ``solve``, ``tracer``, ``metrics``, ``dht``,
    and calls :meth:`_init_async` from ``__init__``.  The pool is lazy: a
    purely synchronous engine never spawns a thread.
    """

    # ------------------------------------------------------------------
    def _init_async(self, max_workers: int, queue_depth: Optional[int]):
        if max_workers < 1:
            raise ValueError(f"max_workers must be >= 1, got {max_workers}")
        self._async_workers = int(max_workers)
        self._async_depth = (2 * self._async_workers if queue_depth is None
                             else int(queue_depth))
        if self._async_depth < 1:
            raise ValueError(
                f"queue_depth must be >= 1, got {self._async_depth}")
        self._async_lock = threading.Lock()
        self._async_queue: Optional[queue.Queue] = None
        self._async_threads: List[threading.Thread] = []
        self._async_closed = False

    def _ensure_pool(self) -> queue.Queue:
        with self._async_lock:
            if self._async_closed:
                raise RuntimeError(
                    "engine is shut down; create a new AmpcEngine to submit")
            if self._async_queue is None:
                self._async_queue = queue.Queue(maxsize=self._async_depth)
                for i in range(self._async_workers):
                    t = threading.Thread(
                        target=self._worker_loop, name=f"ampc-worker-{i}",
                        daemon=True)
                    t.start()
                    self._async_threads.append(t)
            return self._async_queue

    # -- metrics helpers ---------------------------------------------------
    def _async_observe_submit(self, problem: str):
        m = self.metrics
        if m is None:
            return
        m.counter("engine_async_submitted_total",
                  labelnames=("problem",)).inc(1, problem=problem)
        m.gauge("engine_async_inflight").inc(1)

    def _async_on_terminal(self, fut: AmpcFuture):
        m = self.metrics
        if m is None:
            return
        if fut.cancelled():
            m.counter("engine_async_cancelled_total",
                      labelnames=("problem",)).inc(1, problem=fut.problem)
        m.gauge("engine_async_inflight").inc(-1)

    # ------------------------------------------------------------------
    def submit(self, graph, problem: str, *, seed: Optional[int] = None,
               epsilon: Optional[float] = None, timeout: Optional[float] = None,
               deadline: Optional[float] = None, retries: int = 2,
               snapshot=None, **opts) -> AmpcFuture:
        """Enqueue ``solve(graph, problem)`` on the worker pool.

        ``timeout`` (seconds from now) or ``deadline`` (absolute
        ``time.monotonic()`` value) bound the *queue* wait: a future whose
        deadline passes before a worker picks it up fails with
        ``TimeoutError`` instead of launching (a running solve is one
        jitted launch and is never interrupted mid-flight).  ``retries``
        is the transient-failure retry budget forwarded to
        :func:`repro.runtime.retry.resilient_call`.  ``snapshot`` is a
        :class:`~repro.ampc.session.GraphSnapshot` (sessions pass it).

        Validation errors (unknown problem, missing weights, …) raise
        synchronously here, not on the future.  When the bounded queue is
        full, ``submit`` blocks — backpressure toward the producer.
        """
        from . import registry
        spec = registry.get(problem)          # raise unknown-problem now
        self._validate(spec, graph)
        if timeout is not None:
            deadline = time.monotonic() + float(timeout)
        call_opts = dict(opts)
        if seed is not None:
            call_opts["seed"] = seed
        if epsilon is not None:
            call_opts["epsilon"] = epsilon
        if snapshot is not None:
            call_opts["snapshot"] = snapshot
        q = self._ensure_pool()
        fut = AmpcFuture(graph, spec.name, call_opts, deadline=deadline,
                         retries=retries)
        fut._on_terminal = self._async_on_terminal
        self._async_observe_submit(spec.name)
        while True:
            # bounded-queue backpressure, but never wedge on a pool that
            # was shut down underneath a blocked producer
            try:
                q.put(fut, timeout=0.1)
                return fut
            except queue.Full:
                with self._async_lock:
                    if self._async_closed:
                        fut.cancel()
                        raise RuntimeError(
                            "engine shut down while submit was blocked on "
                            "a full queue") from None

    def submit_many(self, graphs: Sequence[Any], problem: str,
                    **kwargs) -> List[AmpcFuture]:
        """``submit`` each graph; returns futures in input order.

        Backpressure applies per submit: with a bounded queue this call
        paces itself against the pool instead of buffering the whole fleet.
        """
        return [self.submit(g, problem, **kwargs) for g in graphs]

    # ------------------------------------------------------------------
    def shutdown(self, drain: bool = True, timeout: Optional[float] = None):
        """Stop the pool.  ``drain=True`` serves every queued future first;
        ``drain=False`` cancels queued futures (running solves still finish).
        Later ``submit`` calls raise ``RuntimeError``.  Idempotent."""
        with self._async_lock:
            already = self._async_closed
            self._async_closed = True
            q = self._async_queue
            threads = list(self._async_threads)
        if q is None or (already and not threads):
            return
        if not drain:
            # empty the queue; anything still pending is cancelled
            while True:
                try:
                    item = q.get_nowait()
                except queue.Empty:
                    break
                if item is not _STOP and isinstance(item, AmpcFuture):
                    item.cancel()
                q.task_done()
        for _ in threads:
            q.put(_STOP)
        deadline = None if timeout is None else time.monotonic() + timeout
        for t in threads:
            t.join(timeout if deadline is None
                   else max(deadline - time.monotonic(), 0.0))
        with self._async_lock:
            self._async_threads = [t for t in self._async_threads
                                   if t.is_alive()]

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        self.shutdown(drain=exc_type is None)
        return False

    # ------------------------------------------------------------------
    def _worker_loop(self):
        q = self._async_queue
        while True:
            item = q.get()
            try:
                if item is _STOP:
                    return
                self._run_future(item)
            finally:
                q.task_done()

    def _run_future(self, fut: AmpcFuture):
        wait_s = time.monotonic() - fut._enqueued_at
        if not fut._try_start():
            return                             # cancelled while queued
        if fut.deadline is not None and time.monotonic() > fut.deadline:
            fut._finish(exc=FutureTimeout(
                f"future {fut.future_id} ({fut.problem}) missed its "
                f"deadline after {wait_s:.3f}s in the pool queue"))
            return
        tracer = self.tracer
        try:
            if tracer.enabled:
                # the owning future's span: the queue wait, every retry's
                # WARN event (runtime.retry attaches to the innermost open
                # span of *this* thread), and the attempts' solve spans all
                # land here
                with tracer.span("solve[async]", problem=fut.problem,
                                 backend=self.dht.name,
                                 future=fut.future_id) as span:
                    span.event("queue_wait", wait_s=round(wait_s, 6))
                    fut.span = span
                    res = self._solve_attempts(fut)
                    res.trace = span
            else:
                res = self._solve_attempts(fut)
        except BaseException as e:  # noqa: BLE001 - surfaced via .result()
            fut._finish(exc=e)
            return
        res.stats.setdefault("async", {
            "future": fut.future_id, "queue_wait_s": round(wait_s, 6),
            "worker": threading.current_thread().name})
        fut._finish(result=res)

    def _solve_attempts(self, fut: AmpcFuture):
        """One-or-more solve attempts through the transient-retry path.

        Each attempt is a full ``solve`` with a **fresh** ledger, so a
        retried solve never double-counts rounds or queries; the result's
        ledger always describes exactly the attempt that succeeded.
        """
        return resilient_call(self.solve, fut.graph, fut.problem,
                              _retries=fut.retries, **fut.opts)
