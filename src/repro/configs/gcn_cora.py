"""gcn-cora: 2 layers, d_hidden=16, mean/sym-norm aggregation."""
from ..models.gnn.gcn import GCNConfig
CONFIG = GCNConfig()
SMOKE = GCNConfig()
