"""mace: 2 layers, 128 channels, l_max=2, correlation 3, 8 RBF, E(3)-ACE."""
from ..models.gnn.mace import MACEConfig
CONFIG = MACEConfig()
SMOKE = MACEConfig(d_hidden=16, n_rbf=4)
