"""jit wrapper with impl switch for dht_gather (cached gather)."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import dht_gather_pallas
from .ref import dht_gather_ref


def dht_gather(table, keys, impl: str = "pallas", interpret: bool | None = None,
               block_q: int = 64, presorted: bool = False):
    """Gather table rows for a key batch with the caching optimization.

    ``keys`` may be any length (the sorted batch is padded with trailing
    ``-1`` lanes up to the block grid; pad lanes are invalid, so they
    produce no loads and no hits) and may contain negative entries, which
    are treated as invalid and return zero rows.  ``interpret=None``
    resolves by platform (compiled on TPU, interpreter elsewhere).

    Returns (out, cache_hits_total); ``cache_hits_total`` counts adjacent
    duplicate *valid* keys in sorted order, i.e. exactly
    ``n_valid - n_distinct_valid``.
    """
    if not presorted:
        order = jnp.argsort(keys)
        sk = keys[order]
    else:
        order = None
        sk = keys
    q = sk.shape[0]
    if impl == "pallas":
        bq = min(block_q, q)
        pad = (-q) % bq if bq else 0
        padded = jnp.concatenate(
            [sk, jnp.full((pad,), -1, jnp.int32)]) if pad else sk
        out, hits = dht_gather_pallas(table, padded, block_q=bq,
                                      interpret=interpret)
        out = out[:q]
        total_hits = hits.sum()
    else:
        out = dht_gather_ref(table, sk)
        total_hits = ((sk[1:] == sk[:-1]) & (sk[1:] >= 0)).sum()
    if order is not None:
        inv = jnp.zeros_like(order).at[order].set(
            jnp.arange(order.shape[0], dtype=order.dtype))
        out = out[inv]
    return out, total_hits
