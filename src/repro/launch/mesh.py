"""Production mesh construction.

A function (not a module-level constant) so importing never touches jax
device state.  Single pod: 16x16 = 256 chips ("data", "model").  Multi-pod:
2x16x16 = 512 chips ("pod", "data", "model") — data parallel across pods
(gradient all-reduce over the slower inter-pod links), TP inside.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """The batch-sharding axes for this mesh (pod folded into data)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def n_chips(mesh) -> int:
    import numpy as np
    return int(np.prod(list(mesh.shape.values())))
