"""Maximal independent set (paper Proposition 4.2 / Section 5.3 case study).

Both implementations compute the *lexicographically-first MIS* over a random
vertex permutation π — identical output to the sequential greedy (oracle).

``mis_ampc``  — the AMPC algorithm of Figure 1: one shuffle builds the
  rank-directed graph and writes it to the DHT; one launch then resolves every
  vertex by adaptive queries against that immutable snapshot.  The per-machine
  recursion of Yoshida et al. becomes an in-round dependency-fixpoint: a
  vertex joins when all lower-rank neighbours are OUT; a vertex is OUT when a
  neighbour is IN.  Fischer–Noever gives O(log n) fixpoint iterations w.h.p.;
  all iterations read the same snapshot, so this is 2 AMPC rounds total.
  Query/byte counters reproduce the paper's Fig 3/4/9 measurements, including
  the caching (dedup) savings.

``mis_mpc_rootset`` — the MPC baseline of Figure 2: the same rule, but each
  phase is a materialized launch with 2 shuffles (join + removal), O(log n)
  phases.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.coo import UGraph
from .rounds import RoundLedger, nbytes_of

UNKNOWN, IN, OUT = 0, 1, 2


@functools.partial(jax.jit, static_argnames=("n",))
def _mis_fixpoint(senders, receivers, rank, n: int):
    """Run the LFMIS fixpoint to completion inside one program.

    Returns (status(n,), iters, queries_nodedup, queries_dedup).
    Query accounting per wave: every undecided vertex fetches the status of
    each of its neighbours (no-dedup count); with caching each *distinct*
    neighbour is fetched once per machine — we model the per-wave dedup as
    one fetch per distinct queried vertex (paper Section 5.3).
    """
    E = senders.shape[0]
    status0 = jnp.zeros((n,), jnp.int32)

    def cond(s):
        status, it, q0, q1 = s
        return jnp.any(status == UNKNOWN)

    def body(s):
        status, it, q0, q1 = s
        s_unk = status[senders] == UNKNOWN
        lower = rank[receivers] < rank[senders]
        # does sender have any lower-rank neighbour that is not OUT?
        blocked = s_unk & lower & (status[receivers] != OUT)
        has_block = jax.ops.segment_max(blocked.astype(jnp.int32), senders,
                                        num_segments=n)
        nbr_in = s_unk & (status[receivers] == IN)
        has_in = jax.ops.segment_max(nbr_in.astype(jnp.int32), senders,
                                     num_segments=n)
        unk = status == UNKNOWN
        status = jnp.where(unk & (has_in > 0), OUT, status)
        status = jnp.where(unk & (has_in <= 0) & (has_block <= 0), IN, status)
        # queries: edges scanned this wave (sender undecided)
        scanned = s_unk.sum()
        # dedup: distinct receivers queried this wave
        probe = jnp.zeros((n,), jnp.int32).at[
            jnp.where(s_unk, receivers, n)].set(1, mode="drop")
        distinct = probe.sum()
        return status, it + 1, q0 + scanned, q1 + distinct

    status, iters, q0, q1 = jax.lax.while_loop(
        cond, body, (status0, jnp.int32(0), jnp.int32(0), jnp.int32(0)))
    return status, iters, q0, q1


def mis_ampc(g: UGraph, seed: int = 0,
             ledger: Optional[RoundLedger] = None,
             caching: bool = True) -> Tuple[np.ndarray, dict]:
    """Returns (in_mis bool(n,), stats)."""
    ledger = ledger if ledger is not None else RoundLedger("ampc_mis")
    n = g.n
    rng = np.random.default_rng(seed)
    rank = rng.permutation(n).astype(np.float32)

    # shuffle 1: build the rank-directed graph, write to the DHT (Fig 1 step 1-2)
    with ledger.shuffle("DirectEdges+WriteKV", nbytes_of(g.edges) * 2):
        s, r, _, _ = g.symmetric()
        senders = jnp.asarray(s); receivers = jnp.asarray(r)
        jrank = jnp.asarray(rank)

    # shuffle 2: IsInMIS search — adaptive queries against the snapshot
    with ledger.shuffle("IsInMIS", n * 4):
        status, iters, q0, q1 = _mis_fixpoint(senders, receivers, jrank, n)
        status = np.asarray(jax.device_get(status))
        it = int(jax.device_get(iters))
        qn = int(jax.device_get(q0)); qd = int(jax.device_get(q1))
    queries = qd if caching else qn
    row_bytes = 8  # nodeid + status
    ledger.record_queries(queries, queries * row_bytes, waves=it,
                          deduped_away=(qn - qd) if caching else 0)
    assert not (status == UNKNOWN).any()
    return status == IN, {"fixpoint_iters": it, "queries_nodedup": qn,
                          "queries_dedup": qd,
                          "cache_savings_factor": qn / max(qd, 1)}


def mis_mpc_rootset(g: UGraph, seed: int = 0,
                    ledger: Optional[RoundLedger] = None,
                    max_phases: int = 500) -> Tuple[np.ndarray, dict]:
    ledger = ledger if ledger is not None else RoundLedger("mpc_mis")
    n = g.n
    rng = np.random.default_rng(seed)
    rank = jnp.asarray(rng.permutation(n).astype(np.float32))
    s, r, _, _ = g.symmetric()
    senders = jnp.asarray(s); receivers = jnp.asarray(r)

    @jax.jit
    def phase(status):
        s_unk = status[senders] == UNKNOWN
        lower = rank[receivers] < rank[senders]
        blocked = s_unk & lower & (status[receivers] != OUT)
        has_block = jax.ops.segment_max(blocked.astype(jnp.int32), senders,
                                        num_segments=n)
        nbr_in = s_unk & (status[receivers] == IN)
        has_in = jax.ops.segment_max(nbr_in.astype(jnp.int32), senders,
                                     num_segments=n)
        unk = status == UNKNOWN
        status = jnp.where(unk & (has_in > 0), OUT, status)
        status = jnp.where(unk & (has_in <= 0) & (has_block <= 0), IN, status)
        return status, (status == UNKNOWN).sum()

    status = jnp.zeros((n,), jnp.int32)
    phases = 0
    nb = nbytes_of(g.edges) * 2
    remaining = n
    while remaining > 0 and phases < max_phases:
        # paper Fig 2: 2 shuffles per phase (mark-to-remove join, removal join)
        with ledger.shuffle(f"rootset_mark_{phases}", nb):
            status, rem = phase(status)
        with ledger.shuffle(f"rootset_remove_{phases}", nb):
            remaining = int(jax.device_get(rem))
        phases += 1
    status = np.asarray(jax.device_get(status))
    return status == IN, {"phases": phases}
