"""Problem registry: every AMPC algorithm and MPC baseline under one roof.

Mirrors ``configs/registry.py``: a decorator registers each solver with a
*normalized* signature so ``AmpcEngine.solve(graph, "<name>")`` can dispatch
without per-algorithm special cases.  Registered functions take
``fn(ctx, graph, **opts)`` where ``ctx`` is an ``engine.SolveContext``
carrying the ledger, the DHT backend, and the engine's seed/epsilon — the
things every pre-engine call site used to thread by hand.

A problem may additionally carry a *batch adapter* (``@batched_impl``)
with signature ``fn(bctx, batch, **opts)``; ``AmpcEngine.solve_many``
dispatches to it per shape bucket and falls back to sequential ``solve``
calls when it is absent.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ProblemSpec:
    name: str
    model: str                 # "ampc" | "mpc"
    fn: Callable               # fn(ctx, graph, **opts) -> (output, stats)
    output: str                # "vertex_mask" | "edge_mask" | "labels" | "count"
    needs_weights: bool = False
    needs_cycles: bool = False  # input must be a disjoint union of cycles
    baseline_of: Optional[str] = None  # for MPC baselines: the AMPC problem
    summary: str = ""
    # Table 3: expected shuffle count on the default (sparse) path, or None
    # when the count is input-dependent (MPC baselines, level variants).
    table3_shuffles: Optional[int] = None
    # Batch-safe adapter for AmpcEngine.solve_many:
    # fn(bctx, batch, **opts) -> [(output, stats), ...] aligned with
    # batch.graphs.  None => solve_many falls back to sequential solves.
    batch_fn: Optional[Callable] = None


PROBLEMS: Dict[str, ProblemSpec] = {}
_ALIASES: Dict[str, str] = {}


def problem(name: str, *, model: str, output: str, needs_weights: bool = False,
            needs_cycles: bool = False, baseline_of: Optional[str] = None,
            aliases: Tuple[str, ...] = (), summary: str = "",
            table3_shuffles: Optional[int] = None):
    """Register an algorithm under ``name`` (plus aliases)."""
    assert model in ("ampc", "mpc"), model

    def deco(fn):
        spec = ProblemSpec(name=name, model=model, fn=fn, output=output,
                           needs_weights=needs_weights,
                           needs_cycles=needs_cycles, baseline_of=baseline_of,
                           summary=summary, table3_shuffles=table3_shuffles)
        if name in PROBLEMS or name in _ALIASES:
            raise ValueError(f"duplicate problem registration: {name}")
        # validate every alias before mutating, so a rejected registration
        # leaves the registry untouched
        taken = set(PROBLEMS) | set(_ALIASES) | {name}
        for a in aliases:
            if a in taken:
                raise ValueError(f"alias {a!r} collides with an existing "
                                 "problem or alias")
            taken.add(a)
        PROBLEMS[name] = spec
        for a in aliases:
            _ALIASES[a] = name
        return fn

    return deco


def batched_impl(name: str):
    """Attach a batch-safe ``solve_many`` adapter to a registered problem.

    The adapter receives ``(bctx, batch, **opts)`` — an
    ``engine.BatchSolveContext`` and a ``graph.batching.GraphBatch`` — and
    returns one ``(output, stats)`` pair per graph in the batch, in batch
    order.  Problems without an adapter fall back to sequential ``solve``
    calls inside ``solve_many``.
    """

    def deco(fn):
        key = _ALIASES.get(name, name)
        if key not in PROBLEMS:
            raise KeyError(f"cannot attach batch adapter: unknown problem "
                           f"{name!r}")
        if PROBLEMS[key].batch_fn is not None:
            raise ValueError(f"duplicate batch adapter for {key!r}")
        PROBLEMS[key] = dataclasses.replace(PROBLEMS[key], batch_fn=fn)
        return fn

    return deco


def _ensure_loaded():
    # Solvers self-register on import; lazy to avoid a registry<->solvers cycle.
    from . import solvers  # noqa: F401


def get(name: str) -> ProblemSpec:
    _ensure_loaded()
    key = _ALIASES.get(name, name)
    if key not in PROBLEMS:
        raise KeyError(
            f"unknown problem {name!r}; known: {sorted(PROBLEMS)} "
            f"(aliases: {sorted(_ALIASES)})")
    return PROBLEMS[key]


def names(model: Optional[str] = None):
    _ensure_loaded()
    return sorted(n for n, s in PROBLEMS.items()
                  if model is None or s.model == model)


def specs(model: Optional[str] = None):
    _ensure_loaded()
    return [PROBLEMS[n] for n in names(model)]
