"""Jittable step functions for every architecture family.

These are what the launcher jits and the dry-run lowers:
  * lm_train_step    — fwd + bwd + AdamW update (donated params/opt)
  * lm_prefill_step  — build KV cache + last-position logits
  * lm_decode_step   — one token against a (possibly ring) KV cache
  * gnn_train_step   — loss + grads + AdamW for the four GNN archs
  * rec_train_step / rec_serve_step / rec_retrieval_step
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..models import transformer as tr
from ..models import sasrec as sr
from ..models.gnn import gcn, gin, mace, schnet
from ..models.gnn.common import GraphBatch
from ..optim import adamw

# register GraphBatch as a pytree (n_graphs static)
try:
    jax.tree_util.register_dataclass(
        GraphBatch,
        data_fields=["senders", "receivers", "node_mask", "edge_mask",
                     "graph_ids", "node_feat", "positions", "species",
                     "labels"],
        meta_fields=["n_graphs"])
except ValueError:
    pass  # already registered

GNN_MODULES = {"gcn-cora": gcn, "gin-tu": gin, "schnet": schnet, "mace": mace}


# --------------------------------------------------------------------------
# LM
# --------------------------------------------------------------------------
def lm_train_step(cfg: tr.TransformerConfig, opt_cfg: adamw.AdamWConfig,
                  params, opt_state, tokens, labels, sctx=None):
    n_micro = max(cfg.n_microbatches, 1)
    if n_micro == 1:
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: tr.loss_fn(cfg, p, tokens, labels, sctx=sctx),
            has_aux=True)(params)
    else:
        # gradient accumulation: scan over microbatches (activation memory
        # divided by n_micro; the optimizer update stays one step)
        B = tokens.shape[0]
        assert B % n_micro == 0
        mb = B // n_micro
        tk = tokens.reshape(n_micro, mb, -1)
        lb = labels.reshape(n_micro, mb, -1)

        def one(p, t_l):
            t, l = t_l
            (loss, m), g = jax.value_and_grad(
                lambda pp: tr.loss_fn(cfg, pp, t, l, sctx=sctx),
                has_aux=True)(p)
            return (loss, m), g

        def scan_fn(carry, t_l):
            acc_g, acc_loss, acc_aux = carry
            (loss, m), g = one(params, t_l)
            acc_g = jax.tree.map(lambda a, b: a + b, acc_g, g)
            return (acc_g, acc_loss + loss, acc_aux + m["aux"]), None

        zero_g = jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), params)
        (gsum, loss_sum, aux_sum), _ = jax.lax.scan(
            scan_fn, (zero_g, jnp.float32(0), jnp.float32(0)), (tk, lb))
        grads = jax.tree.map(lambda g: g / n_micro, gsum)
        loss = loss_sum / n_micro
        metrics = {"nll": loss, "aux": aux_sum / n_micro}
    params, opt_state, opt_metrics = adamw.apply_updates(
        opt_cfg, params, grads, opt_state)
    return params, opt_state, {"loss": loss, **metrics, **opt_metrics}


def lm_prefill_step(cfg: tr.TransformerConfig, params, tokens, sctx=None):
    return tr.prefill(cfg, params, tokens, sctx=sctx)


def lm_decode_step(cfg: tr.TransformerConfig, params, cache, token, sctx=None):
    return tr.decode_step(cfg, params, cache, token, sctx=sctx)


def lm_cache_shape(cfg: tr.TransformerConfig, batch: int, seq_len: int):
    """Allocated KV-cache length: bounded by the window when every layer is
    windowed (mixtral); full length if any layer is global (gemma3)."""
    if cfg.sliding_window > 0 and cfg.local_global_ratio == 0:
        S = min(seq_len, cfg.sliding_window)
    else:
        S = seq_len
    return (cfg.n_layers, batch, S, cfg.n_kv_heads, cfg.head_dim)


# --------------------------------------------------------------------------
# GNN
# --------------------------------------------------------------------------
def gnn_train_step(arch_id: str, cfg, opt_cfg: adamw.AdamWConfig,
                   params, opt_state, batch: GraphBatch):
    mod = GNN_MODULES[arch_id]
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: mod.loss_fn(cfg, p, batch), has_aux=True)(params)
    params, opt_state, opt_metrics = adamw.apply_updates(
        opt_cfg, params, grads, opt_state)
    return params, opt_state, {"loss": loss, **metrics, **opt_metrics}


def gnn_forward_step(arch_id: str, cfg, params, batch: GraphBatch):
    return GNN_MODULES[arch_id].forward(cfg, params, batch)


# --------------------------------------------------------------------------
# RecSys
# --------------------------------------------------------------------------
def rec_train_step(cfg: sr.SASRecConfig, opt_cfg: adamw.AdamWConfig,
                   params, opt_state, item_seq, pos_items, neg_items):
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: sr.loss_fn(cfg, p, item_seq, pos_items, neg_items),
        has_aux=True)(params)
    params, opt_state, opt_metrics = adamw.apply_updates(
        opt_cfg, params, grads, opt_state)
    return params, opt_state, {"loss": loss, **metrics, **opt_metrics}


def rec_serve_step(cfg: sr.SASRecConfig, params, item_seq, candidates):
    states = sr.encode(cfg, params, item_seq)
    return sr.score_candidates(cfg, params, states[:, -1], candidates)


def rec_retrieval_step(cfg: sr.SASRecConfig, params, item_seq):
    states = sr.encode(cfg, params, item_seq)
    return sr.retrieval_scores(cfg, params, states[:, -1])
