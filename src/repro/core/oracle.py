"""Sequential numpy oracles — ground truth for every AMPC algorithm.

These mirror the *definitions* in the paper: random-greedy MIS / maximal
matching are uniquely determined by the rank permutation, the MSF is unique
when weights are distinct, connected components are unique.  All JAX
implementations must match these exactly (or by total weight for MSF ties).
"""
from __future__ import annotations

import numpy as np

from ..graph.coo import UGraph


class UnionFind:
    def __init__(self, n: int):
        self.p = np.arange(n, dtype=np.int64)

    def find(self, x: int) -> int:
        root = x
        while self.p[root] != root:
            root = self.p[root]
        while self.p[x] != root:
            self.p[x], x = root, self.p[x]
        return root

    def union(self, a: int, b: int) -> bool:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return False
        self.p[ra] = rb
        return True


def connected_components(g: UGraph) -> np.ndarray:
    """Label array (n,) — min vertex id in each component."""
    uf = UnionFind(g.n)
    for u, v in g.edges:
        uf.union(int(u), int(v))
    roots = np.array([uf.find(i) for i in range(g.n)])
    # canonicalize: min id per component
    lab = np.full(g.n, -1, np.int64)
    order = np.argsort(roots, kind="stable")
    mins = {}
    for i in range(g.n):
        r = roots[i]
        if r not in mins or i < mins[r]:
            mins[r] = i
    for i in range(g.n):
        lab[i] = mins[roots[i]]
    del order
    return lab


def num_components(g: UGraph) -> int:
    return len(np.unique(connected_components(g)))


def kruskal_msf(g: UGraph):
    """Return (edge_index_mask, total_weight). Unique if weights distinct."""
    assert g.weights is not None
    order = np.argsort(g.weights, kind="stable")
    uf = UnionFind(g.n)
    mask = np.zeros(g.m, bool)
    total = 0.0
    for ei in order:
        u, v = g.edges[ei]
        if uf.union(int(u), int(v)):
            mask[ei] = True
            total += float(g.weights[ei])
    return mask, total


def greedy_mis(g: UGraph, rank: np.ndarray) -> np.ndarray:
    """Lexicographically-first MIS over the vertex rank permutation.

    Returns boolean (n,) membership. rank: (n,) distinct floats/ints.
    """
    order = np.argsort(rank, kind="stable")
    in_mis = np.zeros(g.n, bool)
    blocked = np.zeros(g.n, bool)
    indptr, indices, _, _ = g.csr()
    for v in order:
        if not blocked[v]:
            in_mis[v] = True
            blocked[indices[indptr[v]:indptr[v + 1]]] = True
            blocked[v] = True
    return in_mis


def greedy_mm(g: UGraph, edge_rank: np.ndarray) -> np.ndarray:
    """Random-greedy maximal matching by edge rank. Returns bool (m,)."""
    order = np.argsort(edge_rank, kind="stable")
    matched = np.zeros(g.n, bool)
    in_mm = np.zeros(g.m, bool)
    for ei in order:
        u, v = g.edges[ei]
        if not matched[u] and not matched[v]:
            in_mm[ei] = True
            matched[u] = matched[v] = True
    return in_mm


def is_maximal_matching(g: UGraph, in_mm: np.ndarray) -> bool:
    matched = np.zeros(g.n, bool)
    for ei in np.where(in_mm)[0]:
        u, v = g.edges[ei]
        if matched[u] or matched[v]:
            return False  # not a matching
        matched[u] = matched[v] = True
    for u, v in g.edges:
        if not matched[u] and not matched[v]:
            return False  # not maximal
    return True


def is_mis(g: UGraph, in_set: np.ndarray) -> bool:
    for u, v in g.edges:
        if u != v and in_set[u] and in_set[v]:
            return False  # not independent
    indptr, indices, _, _ = g.csr()
    for v in range(g.n):
        if not in_set[v]:
            nbrs = indices[indptr[v]:indptr[v + 1]]
            if not in_set[nbrs].any() if len(nbrs) else True:
                if not (len(nbrs) and in_set[nbrs].any()):
                    return False  # not maximal
    return True


def yoshida_mis_queries(g: UGraph, rank: np.ndarray) -> int:
    """Total query count of the Yoshida et al. recursive MIS process
    (run independently from every vertex, no memoization) — the quantity the
    paper's caching optimization reduces.  Exponential in the worst case; only
    used on small test graphs to sanity check the O(m) average bound."""
    indptr, indices, _, _ = g.csr()
    count = 0

    def in_mis(v, depth=0):
        nonlocal count
        if depth > 60:
            return True
        nbrs = indices[indptr[v]:indptr[v + 1]]
        lower = nbrs[rank[nbrs] < rank[v]]
        for u in lower[np.argsort(rank[lower], kind="stable")]:
            count += 1
            if in_mis(int(u), depth + 1):
                return False
        return True

    for v in range(g.n):
        in_mis(v)
    return count
