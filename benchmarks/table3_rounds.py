"""Table 3 reproduction: shuffles (costly rounds) used by AMPC vs MPC
implementations of MIS / MaximalMatching / MSF (+ connectivity), dispatched
through the AmpcEngine problem registry."""
from __future__ import annotations

from repro.ampc import AmpcEngine, get_problem

from .common import DEFAULT_GRAPHS, GRAPHS, fmt_table
from .registry import bench

# (row label, registry problem name, solve opts)
ALGS = [
    ("AMPC MIS", "mis", {}),
    ("AMPC MM", "matching", {}),
    ("AMPC MSF", "msf", {"skip_ternarize_if_dense": False}),
    ("AMPC CC", "connectivity", {}),
    ("MPC MIS", "mis-mpc", {}),
    ("MPC MM", "matching-mpc", {}),
    ("MPC MSF", "msf-mpc", {}),
    ("MPC CC", "connectivity-mpc", {}),
]


@bench("table3_rounds", takes_graphs=True,
       quick_kwargs={"graph_names": ["rmat12", "er13"]},
       summary="Table 3: materialized shuffles, AMPC vs MPC")
def run(graph_names=None):
    names = graph_names or list(DEFAULT_GRAPHS)
    eng = AmpcEngine(seed=0)
    table = {}
    for gname in names:
        g = GRAPHS[gname]()
        gw = g.with_random_weights(0)
        for aname, prob, opts in ALGS:
            gin = gw if get_problem(prob).needs_weights else g
            res = eng.solve(gin, prob, **opts)
            table.setdefault(aname, {})[gname] = res.ledger["shuffles"]
    rows = [[aname] + [table[aname][g] for g in names]
            for aname, _, _ in ALGS]
    out = fmt_table(["Algorithm (shuffles)"] + names, rows)
    print(out)
    return {"table": table, "markdown": out}


if __name__ == "__main__":
    run()
