import os
os.environ["XLA_FLAGS"] = (os.environ.get("_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out results/dryrun.jsonl

For each cell: ``jit(step).lower(**input_specs)`` then ``.compile()`` on the
16x16 (single-pod) and 2x16x16 (multi-pod) meshes; records
``memory_analysis()`` (proves it fits), ``cost_analysis()`` (FLOPs/bytes) and
the parsed collective schedule for EXPERIMENTS.md §Dry-run/§Roofline.
"""
import argparse
import json
import time
import traceback

import jax


def run_cell(arch: str, shape: str, multi_pod: bool, skip_reason=None) -> dict:
    from .mesh import make_production_mesh, n_chips
    from .specs import build_lowerable
    from .hlo import analyze_hlo, roofline_terms

    rec = {"arch": arch, "shape": shape,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if skip_reason:
        rec["status"] = "skipped"
        rec["reason"] = skip_reason
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        low = build_lowerable(arch, shape, mesh)
        lowered = low.lower(mesh)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        analysis = analyze_hlo(compiled.as_text())
        chips = n_chips(mesh)
        terms = roofline_terms(analysis, chips, low.model_flops)
        terms["xla_cost_flops_unscaled"] = float(cost.get("flops", 0.0))
        rec.update({
            "status": "ok",
            "notes": low.notes,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "chips": chips,
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_bytes": getattr(
                    mem, "generated_code_size_in_bytes", None),
                # v5e-class chip: 16 GB HBM; arguments live in HBM, outputs
                # alias donated inputs for train steps
                "fits_hbm16": (getattr(mem, "argument_size_in_bytes", 0)
                               + getattr(mem, "temp_size_in_bytes", 0)) < 16e9,
            },
            "roofline": terms,
        })
    except Exception as e:  # noqa: BLE001 - record the failure verbatim
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--include-skipped", action="store_true")
    args = ap.parse_args()

    from ..configs.registry import all_cells, get

    cells = []
    if args.all:
        for aid, sname, skip in all_cells():
            cells.append((aid, sname, skip))
    else:
        entry = get(args.arch)
        shapes = [args.shape] if args.shape else list(entry.shapes)
        for sname in shapes:
            cells.append((args.arch, sname, entry.skip_shapes.get(sname)))

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    out_f = open(args.out, "a") if args.out else None
    for aid, sname, skip in cells:
        for mp in meshes:
            rec = run_cell(aid, sname, mp, skip_reason=skip)
            line = json.dumps(rec)
            print(line if rec["status"] != "ok" else
                  f"OK {aid} {sname} {rec['mesh']} "
                  f"compile={rec['compile_s']}s "
                  f"dom={rec['roofline']['dominant']} "
                  f"roofline={rec['roofline']['roofline_fraction']:.3f}",
                  flush=True)
            if out_f:
                out_f.write(line + "\n")
                out_f.flush()
    if out_f:
        out_f.close()


if __name__ == "__main__":
    main()
