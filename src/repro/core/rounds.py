"""Round / query / byte accounting for AMPC and MPC executions.

The paper measures (Table 3, Fig 3, Fig 9):
  * shuffles  — materialized rounds (Flume stages writing to durable storage);
  * bytes shuffled — data written by shuffles;
  * DHT communication — bytes of key-value store queries + answers;
  * query count — number of KV lookups.

Here a "shuffle" is a materialized jitted-program launch whose outputs are
committed (and, under the fault-tolerant runtime, checkpointed).  Adaptive
in-round query waves performed via ``lax.while_loop`` count queries/DHT bytes
but not shuffles — exactly the AMPC accounting.  MPC baselines call
``ledger.shuffle`` once per phase instead.

Observability wiring (``repro.obs``): a ledger may carry a ``tracer`` and a
``metrics`` registry.  Every shuffle then becomes a span (named
``shuffle:<name>``, carrying its bytes) and every counter update lands in
the engine-wide metric series (``shuffles_total``, ``dht_queries_total``,
…) labeled by ``algorithm``.  Both default to disabled no-ops, so a bare
``RoundLedger`` behaves exactly as before.

Raw-string event accumulation is gated behind ``record_events``: the
structured trace supersedes the strings, and long-lived engines serving
``solve_many`` traffic must not grow an unbounded list per solve (the
engine creates bucket-loop ledgers with ``record_events=False``).
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Dict, List

@dataclasses.dataclass
class RoundLedger:
    algorithm: str = ""
    shuffles: int = 0
    bytes_shuffled: int = 0
    dht_queries: int = 0
    dht_bytes: int = 0
    dht_query_waves: int = 0
    dedup_savings: int = 0  # queries avoided by the caching optimization
    dht_overflows: int = 0  # routed-router capacity overflows (0 = exact)
    wall_time_s: float = 0.0
    phase_times: Dict[str, float] = dataclasses.field(default_factory=dict)
    events: List[str] = dataclasses.field(default_factory=list)
    # observability hooks (repro.obs); None => disabled
    tracer: Any = dataclasses.field(repr=False, compare=False, default=None)
    metrics: Any = dataclasses.field(repr=False, compare=False, default=None)
    record_events: bool = dataclasses.field(compare=False, default=True)

    # -- shuffle (materialized round) -------------------------------------
    @contextlib.contextmanager
    def shuffle(self, name: str, nbytes: int = 0):
        tracer = self.tracer
        t0 = time.perf_counter()
        if tracer is not None and tracer.enabled:
            with tracer.span(f"shuffle:{name}", algorithm=self.algorithm,
                             nbytes=int(nbytes)):
                yield
        else:
            yield
        self._count_shuffle(name, nbytes, time.perf_counter() - t0)

    def record_shuffle(self, name: str, nbytes: int = 0,
                       seconds: float = 0.0):
        """Record one materialized round without timing a ``with`` block.

        Used by batched (``solve_many``) launches, where one physical launch
        serves many per-graph ledgers: each ledger records its own shuffle
        entry with its share of the bytes and wall time.  With a tracer the
        share becomes a retroactive span under the current open span.
        """
        tracer = self.tracer
        if tracer is not None and tracer.enabled:
            tracer.record_span(f"shuffle:{name}", dur_s=seconds,
                               algorithm=self.algorithm, nbytes=int(nbytes))
        self._count_shuffle(name, nbytes, seconds)

    def _count_shuffle(self, name: str, nbytes: int, seconds: float):
        self.shuffles += 1
        self.bytes_shuffled += int(nbytes)
        self.wall_time_s += seconds
        self.phase_times[name] = self.phase_times.get(name, 0.0) + seconds
        if self.record_events:
            self.events.append(f"shuffle:{name}:{nbytes}B:{seconds:.4f}s")
        if self.metrics is not None:
            self.metrics.counter(
                "shuffles_total", labelnames=("algorithm",)).inc(
                    1, algorithm=self.algorithm)
            self.metrics.counter(
                "bytes_shuffled_total", labelnames=("algorithm",)).inc(
                    int(nbytes), algorithm=self.algorithm)

    # -- DHT traffic -------------------------------------------------------
    def record_queries(self, n_queries: int, nbytes: int, waves: int = 1,
                       deduped_away: int = 0, overflow: int = 0):
        self.dht_queries += int(n_queries)
        self.dht_bytes += int(nbytes)
        self.dht_query_waves += int(waves)
        self.dedup_savings += int(deduped_away)
        self.dht_overflows += int(overflow)
        tracer = self.tracer
        if tracer is not None and tracer.enabled:
            tracer.event("dht_queries", queries=int(n_queries),
                         nbytes=int(nbytes), waves=int(waves),
                         deduped_away=int(deduped_away),
                         overflow=int(overflow))
        m = self.metrics
        if m is not None:
            labels = {"labelnames": ("algorithm",)}
            kw = {"algorithm": self.algorithm}
            m.counter("dht_queries_total", **labels).inc(int(n_queries), **kw)
            m.counter("dht_bytes_total", **labels).inc(int(nbytes), **kw)
            m.counter("dht_query_waves_total", **labels).inc(int(waves), **kw)
            if deduped_away:
                m.counter("dedup_savings_total", **labels).inc(
                    int(deduped_away), **kw)
            if overflow:
                m.counter("dht_overflows_total", **labels).inc(
                    int(overflow), **kw)

    def summary(self) -> Dict:
        return {
            "algorithm": self.algorithm,
            "shuffles": self.shuffles,
            "bytes_shuffled": self.bytes_shuffled,
            "dht_queries": self.dht_queries,
            "dht_bytes": self.dht_bytes,
            "dht_query_waves": self.dht_query_waves,
            "dedup_savings": self.dedup_savings,
            "dht_overflows": self.dht_overflows,
            "wall_time_s": round(self.wall_time_s, 4),
            "phase_times": {k: round(v, 4) for k, v in self.phase_times.items()},
        }


def nbytes_of(*arrays) -> int:
    total = 0
    for a in arrays:
        if a is None:
            continue
        total += a.size * a.dtype.itemsize
    return int(total)
