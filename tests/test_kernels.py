"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp oracles."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.segment_matmul.ops import segment_matmul
from repro.kernels.segment_matmul.ref import segment_matmul_ref
from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.dht_gather.ops import dht_gather
from repro.kernels.dht_gather.ref import dht_gather_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------- flash attn
@pytest.mark.parametrize("B,S,K,H,Hkv,D", [
    (1, 128, 128, 4, 4, 32),      # MHA square
    (2, 256, 256, 4, 2, 64),      # GQA
    (1, 128, 384, 8, 8, 32),      # cross (decode-style, q shorter)
    (2, 256, 256, 8, 2, 128),     # GQA wide head
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
def test_flash_attention_matches_ref(B, S, K, H, Hkv, D, dtype, causal, window):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), dtype)
    k = jnp.asarray(rng.standard_normal((B, K, Hkv, D)), dtype)
    v = jnp.asarray(rng.standard_normal((B, K, Hkv, D)), dtype)
    got = flash_attention_fwd(q, k, v, causal=causal, window=window,
                              block_q=64, block_kv=64, interpret=True)
    want = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_attention_block_shape_independence():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 256, 2, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 256, 2, 64)), jnp.float32)
    a = flash_attention_fwd(q, k, v, block_q=64, block_kv=128, interpret=True)
    b = flash_attention_fwd(q, k, v, block_q=128, block_kv=64, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


# ------------------------------------------------------------ segment_matmul
@pytest.mark.parametrize("N,K,D,F", [(32, 3, 16, 8), (64, 8, 32, 32),
                                     (16, 15, 64, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_matmul_matches_ref(N, K, D, F, dtype):
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((N, D)), dtype)
    nbr = rng.integers(-1, N, (N, K)).astype(np.int32)
    w = jnp.asarray(rng.standard_normal((D, F)), dtype)
    got = segment_matmul(x, jnp.asarray(nbr), w, impl="pallas", interpret=True)
    # the kernel accumulates in f32; compare both against the f32 oracle
    want32 = segment_matmul_ref(x.astype(jnp.float32), jnp.asarray(nbr),
                                w.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want32, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=2e-1 if dtype == jnp.bfloat16 else 1e-4)


# -------------------------------------------------------------- embedding_bag
@pytest.mark.parametrize("V,D,B,L", [(64, 16, 16, 4), (256, 32, 32, 10),
                                     (1024, 64, 8, 50)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag_matches_ref(V, D, B, L, dtype):
    rng = np.random.default_rng(3)
    table = jnp.asarray(rng.standard_normal((V, D)), dtype)
    ids = rng.integers(0, V, (B, L)).astype(np.int32)
    ids[:, -1] = 0   # padding
    got = embedding_bag(table, jnp.asarray(ids), impl="pallas", interpret=True)
    want = embedding_bag_ref(table, jnp.asarray(ids))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=5e-2 if dtype == jnp.bfloat16 else 1e-4)


# ----------------------------------------------------------------- dht_gather
@pytest.mark.parametrize("V,D,Q", [(64, 16, 64), (256, 32, 128)])
def test_dht_gather_matches_take(V, D, Q):
    rng = np.random.default_rng(4)
    table = jnp.asarray(rng.standard_normal((V, D)).astype(np.float32))
    keys = rng.integers(0, V, Q).astype(np.int32)
    keys[10:20] = keys[10]        # duplicates -> cache hits
    out, hits = dht_gather(table, jnp.asarray(keys), impl="pallas",
                           interpret=True)
    want = np.asarray(table)[keys]
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-5, atol=1e-5)
    assert int(hits) >= 9         # the duplicated run reuses the cached row


def test_dht_gather_cache_hit_count_exact():
    table = jnp.asarray(np.eye(8, 4, dtype=np.float32))
    keys = jnp.asarray(np.array([3, 3, 3, 5, 5, 1, 1, 1], np.int32))
    out, hits = dht_gather(table, keys, impl="pallas", interpret=True,
                           presorted=False)
    # sorted: [1,1,1,3,3,3,5,5] -> 5 adjacent duplicates
    assert int(hits) == 5
    ref = dht_gather_ref(table, jnp.sort(keys))
    assert np.isfinite(np.asarray(out)).all()
