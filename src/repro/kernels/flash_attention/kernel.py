"""Pallas TPU flash attention (fwd): GQA, causal, optional sliding window.

Tiling: grid (B, H, num_q_blocks, num_kv_blocks); the kv dimension is the
innermost (sequential on TPU), accumulating online-softmax state in VMEM
scratch; the output block is written on the last kv step.  Causal + window
blocks that are fully masked are skipped with ``pl.when`` (no MXU work).

Block shapes default to (128, head_dim) q-tiles × (128, head_dim) kv-tiles —
MXU-aligned for head_dim ∈ {128, 256}.  Validated in interpret mode against
ref.attention_ref across shapes/dtypes (tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30  # python float: pallas kernels must not capture array constants


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int, bq: int, bk: int,
                  nk: int, q_offset: int):
    ki = pl.program_id(3)
    qi = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq + q_offset          # absolute position of q block
    k_start = ki * bk
    # skip fully-masked blocks (strictly above the causal diagonal or
    # entirely outside the window)
    must_compute = True
    if causal:
        must_compute = k_start <= q_start + bq - 1
    if window > 0:
        must_compute = jnp.logical_and(
            must_compute, k_start + bk - 1 > q_start - window) \
            if causal else must_compute

    @pl.when(must_compute)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)       # (bq, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)       # (bk, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        diff = qpos - kpos
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= diff >= 0
        if window > 0:
            mask &= diff < window
        logits = jnp.where(mask, logits, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, logits.max(-1, keepdims=True))
        p = jnp.exp(logits - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, :, 0, :] = (acc_scr[...] /
                             jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_kv", "interpret"))
def flash_attention_fwd(q, k, v, causal: bool = True, window: int = 0,
                        block_q: int = 128, block_kv: int = 128,
                        interpret: bool = True):
    """q: (B, S, H, D); k/v: (B, K, Hkv, D) -> (B, S, H, D)."""
    B, Sq, H, D = q.shape
    Kk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    bq = min(block_q, Sq)
    bk = min(block_kv, Kk)
    assert Sq % bq == 0 and Kk % bk == 0
    nq, nk = Sq // bq, Kk // bk
    scale = 1.0 / np.sqrt(D)
    q_offset = Kk - Sq  # decode alignment: last q attends last k

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, nk=nk, q_offset=q_offset)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda b, h, qi, ki, G=G: (b, ki, h // G, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda b, h, qi, ki, G=G: (b, ki, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
