"""Observability for the AMPC engine: tracing, metrics, exporters.

Three self-contained layers, wired through the engine / ledger / backends:

* :mod:`repro.obs.trace`   — span-based tracer (nested spans, wall time,
  attributes, thread-safe collection) with an allocation-free no-op path
  when tracing is disabled;
* :mod:`repro.obs.metrics` — engine-wide metrics registry (counters,
  gauges, histograms with labels) plus the canonical ``ENGINE_METRICS``
  table the docs are checked against;
* :mod:`repro.obs.export`  — Chrome-trace/Perfetto JSON, JSONL event log,
  and plain-text metrics reports.

Quickstart::

    from repro.ampc import AmpcEngine
    from repro.obs import export

    eng = AmpcEngine(trace=True)
    res = eng.solve(graph, "mis")         # res.trace = this solve's span
    export.write_chrome_trace("out.json", eng.tracer)
    print(eng.metrics_report())
"""
from .trace import (NOOP_TRACER, Span, SpanEvent, Tracer, as_tracer,
                    current_tracer, get_default_tracer, set_default_tracer)
from .metrics import (ENGINE_METRICS, MetricDef, MetricsRegistry,
                      default_registry)
from .export import (coverage, iter_spans, metrics_report, to_chrome_trace,
                     write_chrome_trace, write_jsonl)

__all__ = [
    "Tracer", "Span", "SpanEvent", "NOOP_TRACER", "as_tracer",
    "current_tracer", "get_default_tracer", "set_default_tracer",
    "MetricsRegistry", "MetricDef", "ENGINE_METRICS", "default_registry",
    "to_chrome_trace", "write_chrome_trace", "write_jsonl", "iter_spans",
    "metrics_report", "coverage",
]
