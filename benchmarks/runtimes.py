"""Figures 5-8 + Table 4 analog: wall-clock AMPC vs MPC on the benchmark
suite (single-host CPU execution of the same jitted programs; the paper's
absolute times are datacenter-specific, the *ratios* and round counts are
the reproducible claims)."""
from __future__ import annotations

from repro.core import matching as mm, mis, msf, one_vs_two as ovt
from repro.core.rounds import RoundLedger

from .common import CYCLES, GRAPHS, fmt_table, timed
from repro.graph import generators as gen


def run(graph_names=None, cycles=None):
    names = graph_names or list(GRAPHS)
    rows = []
    for gname in names:
        g = GRAPHS[gname]()
        gw = g.with_random_weights(0)
        (_, t_amis) = timed(lambda: mis.mis_ampc(g, seed=0))
        (_, t_mmis) = timed(lambda: mis.mis_mpc_rootset(g, seed=0))
        (_, t_amm) = timed(lambda: mm.mm_ampc(g, seed=0))
        (_, t_mmm) = timed(lambda: mm.mm_mpc_rootset(g, seed=0))
        (_, t_amsf) = timed(lambda: msf.msf_ampc(
            gw, seed=0, skip_ternarize_if_dense=False))
        (_, t_mmsf) = timed(lambda: msf.msf_mpc_boruvka(gw, seed=0))
        rows.append([gname,
                     f"{t_amis:.2f}/{t_mmis:.2f} ({t_mmis/t_amis:.1f}x)",
                     f"{t_amm:.2f}/{t_mmm:.2f} ({t_mmm/t_amm:.1f}x)",
                     f"{t_amsf:.2f}/{t_mmsf:.2f} ({t_mmsf/t_amsf:.1f}x)"])
    out = fmt_table(["graph", "MIS a/m (speedup)", "MM a/m (speedup)",
                     "MSF a/m (speedup)"], rows)
    print(out)

    crows = []
    for cname, k in (cycles or CYCLES).items():
        g2 = gen.two_cycles(k)
        (nc_a, t_a) = timed(lambda: ovt.one_vs_two_ampc(g2, p=1 / 64, seed=0))
        (nc_m, t_m) = timed(lambda: ovt.one_vs_two_mpc(g2, seed=0))
        assert nc_a[0] == 2 and nc_m[0] == 2
        crows.append([cname, f"{t_a:.2f}", f"{t_m:.2f}", f"{t_m/t_a:.1f}x"])
    cout = fmt_table(["cycles", "AMPC s", "MPC s", "speedup"], crows)
    print("\n" + cout)
    print("\npaper: MIS 2.31-3.18x, MM 1.16-1.72x, MSF 2.6-7.19x, "
          "1v2c 3.40-9.87x (100 machines, RDMA)")
    return {"rows": rows, "cycle_rows": crows,
            "markdown": out + "\n\n" + cout}


if __name__ == "__main__":
    run()
