"""mixtral-8x22b: 56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768,
MoE 8 experts top-2, SWA 4096."""
from .lm_archs import MIXTRAL_8X22B as CONFIG, smoke
SMOKE = smoke(CONFIG)
