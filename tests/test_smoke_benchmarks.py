"""Benchmark-drift smoke test: the registry dispatch path must stay green.

Runs ``benchmarks.run --quick --only table3_rounds`` (on the smallest graph
in the suite) through the same registry lookup the CLI uses and fails if
any benchmark returns ``{"error": ...}`` — so a signature drift between the
engine/registry and the benchmark modules is caught by tier-1 pytest
instead of at paper-reproduction time.
"""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def test_benchmark_registry_lists_all_benches():
    from benchmarks import registry
    names = registry.names()
    for expected in ("table3_rounds", "bytes_comm", "mis_caching",
                     "runtimes", "msf_queries", "solve_many",
                     "dht_hot_path", "gnn_dht_hillclimb", "profile_cell",
                     "roofline"):
        assert expected in names, f"{expected} missing from registry"
    spec = registry.get("table3_rounds")
    assert spec.takes_graphs and spec.quick_kwargs.get("graph_names")


def test_quick_table3_through_registry_dispatch():
    """The acceptance gate: --quick --only table3_rounds must succeed."""
    from benchmarks import run as bench_run
    # er10 keeps the smoke run CPU-cheap; --graphs exercises the shared
    # config path that overrides --quick's default subset
    rc = bench_run.main(["--quick", "--only", "table3_rounds",
                         "--graphs", "er10"])
    assert rc == 0, "table3_rounds returned an error through the registry"


def test_unknown_graph_rejected():
    from benchmarks import run as bench_run
    with pytest.raises(SystemExit):
        bench_run.main(["--only", "table3_rounds", "--graphs", "nope"])


def test_quick_trace_produces_valid_chrome_trace(tmp_path):
    """--trace writes a loadable Chrome trace whose bench spans cover
    >= 95% of the measured wall time."""
    import json

    from benchmarks import run as bench_run
    from repro.obs import current_tracer, get_default_tracer, NOOP_TRACER

    out = tmp_path / "trace.json"
    rc = bench_run.main(["--quick", "--only", "table3_rounds",
                         "--graphs", "er10", "--trace", str(out)])
    assert rc == 0
    # the harness tracer must not leak into later engine constructions
    assert get_default_tracer() is NOOP_TRACER
    assert current_tracer() is NOOP_TRACER
    doc = json.loads(out.read_text())
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert any(e["name"] == "bench:table3_rounds" for e in xs)
    for e in xs:
        assert isinstance(e["ts"], int) and isinstance(e["dur"], int)
        assert e["dur"] >= 0 and e["pid"] and "tid" in e
    # solves traced inside the benchmark nest under the bench span
    assert any(e["name"] == "solve" for e in xs)
    wall = doc["otherData"]["measured_wall_us"]
    covered = sum(e["dur"] for e in xs if e["name"].startswith("bench:"))
    assert covered >= 0.95 * wall, \
        f"bench spans cover {covered}/{wall}us (< 95%)"
