"""Corollary 4.1: applications of the AMPC maximal-matching black box.

  * (2+ε)-approximate maximum WEIGHT matching: greedy over edges in
    decreasing-weight order is a 1/2-approximation (Avis '83); running the
    AMPC greedy-MM fixpoint with weight-derived ranks computes exactly that
    greedy in O(1) adaptive rounds.
  * 2-approximate minimum vertex cover: the endpoints of any maximal
    matching.
  * (1+ε)-approximate maximum CARDINALITY matching is obtained by the
    standard augmenting-path boosting over O(1/ε) rounds of maximal
    matchings (we provide the single-round 1/2-approx building block).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from ..graph.coo import UGraph
from .matching import mm_ampc
from .rounds import RoundLedger


def mwm_greedy_ampc(g: UGraph, seed: int = 0,
                    ledger: Optional[RoundLedger] = None
                    ) -> Tuple[np.ndarray, dict]:
    """1/2-approx maximum weight matching: greedy by decreasing weight
    (ties broken by a random permutation), via the AMPC MM fixpoint.
    Returns (in_matching bool(m,), stats)."""
    assert g.weights is not None
    rng = np.random.default_rng(seed)
    tie = rng.permutation(g.m).astype(np.float64) / max(g.m, 1)
    # rank: ascending = processed first => sort by decreasing weight
    order = np.argsort(np.lexsort((tie, -g.weights.astype(np.float64))))
    erank = order.astype(np.float32)

    # run the fixpoint with our custom ranks by monkey-wiring through the
    # same machinery mm_ampc uses (it draws ranks from `seed`; we instead
    # call the fixpoint directly)
    import jax
    import jax.numpy as jnp
    from .matching import _mm_fixpoint, IN
    u = jnp.asarray(g.edges[:, 0]); v = jnp.asarray(g.edges[:, 1])
    led = ledger if ledger is not None else RoundLedger("ampc_mwm")
    with led.shuffle("SortEdgesByWeight+WriteKV", g.m * 12):
        jrank = jnp.asarray(erank)
    with led.shuffle("IsInMWM", g.m):
        st, iters, q0, q1 = _mm_fixpoint(u, v, jrank, g.n,
                                         jnp.zeros((g.m,), jnp.int32))
        st = np.asarray(jax.device_get(st))
    in_mm = st == IN
    w = float(g.weights[in_mm].sum())
    return in_mm, {"weight": w, "iters": int(jax.device_get(iters)),
                   "erank": erank}


def vertex_cover_2approx(g: UGraph, seed: int = 0,
                         ledger: Optional[RoundLedger] = None
                         ) -> Tuple[np.ndarray, dict]:
    """2-approx minimum vertex cover = endpoints of a maximal matching."""
    in_mm, stats = mm_ampc(g, seed=seed, ledger=ledger)
    cover = np.zeros(g.n, bool)
    cover[g.edges[in_mm, 0]] = True
    cover[g.edges[in_mm, 1]] = True
    return cover, {"cover_size": int(cover.sum()), **stats}
