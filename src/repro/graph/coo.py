"""Graph containers used across the framework.

Host-side construction is numpy; algorithm inputs are converted to jnp arrays
with static shapes.  Undirected graphs store each edge once as ``edges[(E,2)]``;
``symmetric()`` produces the doubled directed view used by message passing and
the AMPC query processes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class UGraph:
    """Undirected graph in COO form (each edge stored once, u < v not required)."""

    n: int
    edges: np.ndarray  # (E, 2) int32
    weights: Optional[np.ndarray] = None  # (E,) float32

    def __post_init__(self):
        self.edges = np.asarray(self.edges, dtype=np.int32).reshape(-1, 2)
        if self.weights is not None:
            self.weights = np.asarray(self.weights, dtype=np.float32)
            assert self.weights.shape[0] == self.edges.shape[0]

    @property
    def m(self) -> int:
        return int(self.edges.shape[0])

    def with_unit_weights(self) -> "UGraph":
        return UGraph(self.n, self.edges, np.ones(self.m, np.float32))

    def with_random_weights(self, seed: int = 0) -> "UGraph":
        rng = np.random.default_rng(seed)
        # distinct weights => unique MSF, simplifies testing
        w = rng.permutation(self.m).astype(np.float32) + 1.0
        return UGraph(self.n, self.edges, w)

    def with_degree_weights(self) -> "UGraph":
        """Paper Section 5.2: weight(u,v) proportional to deg(u)+deg(v)."""
        deg = self.degrees()
        w = (deg[self.edges[:, 0]] + deg[self.edges[:, 1]]).astype(np.float32)
        # tie-break by edge id to keep the MSF unique
        w = w + np.arange(self.m, dtype=np.float32) / max(self.m, 1) * 0.5
        return UGraph(self.n, self.edges, w)

    def degrees(self) -> np.ndarray:
        deg = np.zeros(self.n, np.int64)
        np.add.at(deg, self.edges[:, 0], 1)
        np.add.at(deg, self.edges[:, 1], 1)
        return deg

    def dedup(self) -> "UGraph":
        """Remove duplicate undirected edges and self loops (keep min weight)."""
        e = np.sort(self.edges, axis=1)
        keep = e[:, 0] != e[:, 1]
        e = e[keep]
        w = self.weights[keep] if self.weights is not None else None
        if e.shape[0] == 0:
            return UGraph(self.n, e.reshape(0, 2), w)
        key = e[:, 0].astype(np.int64) * self.n + e[:, 1]
        if w is None:
            _, idx = np.unique(key, return_index=True)
            return UGraph(self.n, e[idx], None)
        order = np.lexsort((w, key))
        key_sorted = key[order]
        first = np.ones(len(order), bool)
        first[1:] = key_sorted[1:] != key_sorted[:-1]
        sel = order[first]
        return UGraph(self.n, e[sel], w[sel])

    def symmetric(self):
        """Return (senders, receivers, weights, eids) with both directions."""
        s = np.concatenate([self.edges[:, 0], self.edges[:, 1]])
        r = np.concatenate([self.edges[:, 1], self.edges[:, 0]])
        eid = np.concatenate([np.arange(self.m), np.arange(self.m)]).astype(np.int32)
        if self.weights is not None:
            w = np.concatenate([self.weights, self.weights])
        else:
            w = None
        return s.astype(np.int32), r.astype(np.int32), w, eid

    def csr(self):
        """CSR over the symmetric view: (indptr, indices, weights, eids)."""
        s, r, w, eid = self.symmetric()
        order = np.argsort(s, kind="stable")
        s, r, eid = s[order], r[order], eid[order]
        w = w[order] if w is not None else None
        indptr = np.zeros(self.n + 1, np.int64)
        np.add.at(indptr, s + 1, 1)
        indptr = np.cumsum(indptr)
        return indptr, r, w, eid

    def padded_adj(self, max_deg: Optional[int] = None):
        """Dense (n, max_deg) adjacency with -1 padding.

        Returns (nbr_ids, nbr_weights, nbr_eids). Used after ternarization where
        max_deg <= 3, and for small test graphs.
        """
        indptr, indices, w, eid = self.csr()
        deg = np.diff(indptr)
        md = int(deg.max()) if max_deg is None and self.n else (max_deg or 1)
        md = max(md, 1)
        nbr = np.full((self.n, md), -1, np.int32)
        nbw = np.full((self.n, md), np.inf, np.float32)
        nbe = np.full((self.n, md), -1, np.int32)
        for v in range(self.n):
            a, b = indptr[v], indptr[v + 1]
            k = min(b - a, md)
            nbr[v, :k] = indices[a : a + k]
            if w is not None:
                nbw[v, :k] = w[a : a + k]
            nbe[v, :k] = eid[a : a + k]
        return nbr, nbw, nbe
