"""Benchmark harness entry point:  PYTHONPATH=src python -m benchmarks.run

Runs one benchmark per paper table/figure and the roofline report.
Use --quick for the reduced graph set, --only <name> for a single bench.
"""
from __future__ import annotations

import argparse
import sys
import time

BENCHES = ["table3_rounds", "bytes_comm", "mis_caching", "runtimes",
           "msf_queries", "gnn_dht_hillclimb", "roofline"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=BENCHES)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    selected = [args.only] if args.only else BENCHES
    results = {}
    for name in selected:
        print(f"\n{'='*72}\n== {name}\n{'='*72}", flush=True)
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        kw = {}
        if args.quick and name in ("table3_rounds", "bytes_comm",
                                   "mis_caching", "runtimes"):
            kw = {"graph_names": ["rmat12", "er13"]}
        if args.quick and name == "runtimes":
            kw["cycles"] = {"2x2e3": 2000}
        if args.quick and name == "msf_queries":
            kw = {"log2_sizes": (10, 12)}
        try:
            results[name] = mod.run(**kw)
            print(f"[{name} done in {time.time()-t0:.1f}s]")
        except Exception as e:  # noqa: BLE001
            print(f"[{name} FAILED: {e}]")
            results[name] = {"error": str(e)}
    failed = [k for k, v in results.items() if "error" in v]
    print(f"\n{'='*72}\n{len(selected)-len(failed)}/{len(selected)} "
          f"benchmarks succeeded" + (f"; FAILED: {failed}" if failed else ""))
    return 0 if not failed else 1


if __name__ == "__main__":
    sys.exit(main())
