"""Pluggable DHT backends for the AMPC engine.

The paper's AMPC model has exactly one shared primitive: an immutable
distributed hash table written by the previous round and queried adaptively
inside the current one.  ``core.dht`` provides two execution schedules for
that primitive — a plain device gather (``lookup``) and an explicit
``shard_map`` all_to_all router (``routed_lookup``).  This module promotes
both behind one ``DhtBackend`` protocol so the engine (and any solver) can
issue lookups without knowing which schedule runs underneath, and so ledger
accounting (queries, bytes, dedup savings, waves, overflows) is identical on
both paths.

Backends are stateless between solves: ``snapshot(values)`` binds a value
array + ledger into a ``core.dht.ShardedDHT`` and every query goes through
``ShardedDHT.lookup`` — the single accounting choke point.  ``lookup_many``
is the batched (``solve_many``) variant: one materialized exchange serves a
whole shape bucket, with per-graph query counts split by the padding mask.
"""
from __future__ import annotations

from typing import Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from ..core.dht import ShardedDHT
from ..core.rounds import RoundLedger
from ..obs import trace as obs_trace


@runtime_checkable
class DhtBackend(Protocol):
    """One immutable-snapshot KV store; the only AMPC communication primitive."""

    name: str

    def snapshot(self, values, ledger=None,
                 value_bytes: Optional[int] = None) -> ShardedDHT:
        """Write ``values`` (row i = value of key i) into the DHT."""
        ...

    def lookup(self, values, keys, *, ledger=None, dedup: bool = True,
               value_bytes: Optional[int] = None):
        """One-shot snapshot + query batch (convenience for single reads)."""
        ...

    def lookup_many(self, values, keys, *, ledgers=None, key_mask=None,
                    dedup: bool = False, value_bytes: Optional[int] = None):
        """Batched snapshot read over a graph batch (see ``_BackendBase``)."""
        ...


class _BackendBase:
    def lookup(self, values, keys, *, ledger=None, dedup: bool = True,
               value_bytes: Optional[int] = None):
        return self.snapshot(values, ledger=ledger,
                             value_bytes=value_bytes).lookup(keys, dedup=dedup)

    def lookup_many(self, values, keys, *, ledgers=None, key_mask=None,
                    dedup: bool = False, value_bytes: Optional[int] = None):
        """One materialized exchange serving a whole ``solve_many`` bucket.

        ``values`` is (B, n, ...) — graph ``b``'s snapshot in row ``b`` —
        and ``keys`` is (B, K) int32.  The batch is flattened into a single
        keyspace (graph ``b``'s key ``k`` becomes ``b * n + k``) so both the
        local gather and the routed all_to_all run **once** for the whole
        bucket; graphs cannot alias each other's rows because their key
        ranges are disjoint.

        ``key_mask`` (B, K) marks the real queries: masked lanes become the
        ``-1`` padding keys the DHT ignores.  When ``ledgers`` is given (one
        ``RoundLedger`` per graph, batch order), each graph's ledger records
        *its own* valid-query count and bytes — the per-graph split of the
        batched exchange.  Router overflows are a property of the exchange
        as a whole (any graph's answers may be inexact), so the total is
        recorded on **every** participating ledger: per graph,
        ``dht_overflows == 0`` still certifies exact answers.  Returns the
        gathered (B, K, ...) array.
        """
        values = jnp.asarray(values)
        keys = jnp.asarray(keys, jnp.int32)
        B, n = values.shape[0], values.shape[1]
        tracer = next((led.tracer for led in (ledgers or ())
                       if led is not None and led.tracer is not None
                       and led.tracer.enabled), None)
        if tracer is None:
            # solve_many bucket ledgers carry no tracer (the engine emits
            # per-graph spans retroactively); attach the batched exchange
            # to whatever bucket span is currently open instead
            amb = obs_trace.current_tracer()
            tracer = amb if amb.enabled else None
        if tracer is not None:
            with tracer.span("dht:lookup_many", backend=self.name, batch=B,
                             keys_per_graph=int(keys.shape[1])):
                return self._lookup_many(values, keys, B, n,
                                         ledgers=ledgers, key_mask=key_mask,
                                         dedup=dedup, value_bytes=value_bytes)
        return self._lookup_many(values, keys, B, n, ledgers=ledgers,
                                 key_mask=key_mask, dedup=dedup,
                                 value_bytes=value_bytes)

    def _lookup_many(self, values, keys, B, n, *, ledgers, key_mask, dedup,
                     value_bytes):
        flat_vals = values.reshape((B * n,) + values.shape[2:])
        offset = (jnp.arange(B, dtype=jnp.int32) * n)[:, None]
        flat_keys = keys + offset
        if key_mask is not None:
            flat_keys = jnp.where(jnp.asarray(key_mask), flat_keys, -1)
        # scratch ledger: captures the exchange's overflow count without
        # double-recording the query totals we re-attribute per graph
        # below.  deferred=True keeps it a raw device scalar — nothing
        # here touches the host; the per-graph ledgers decide when.
        scratch = RoundLedger("lookup_many", deferred=True)
        snap = self.snapshot(flat_vals, ledger=scratch,
                             value_bytes=value_bytes)
        out = snap.lookup(flat_keys.reshape(-1), dedup=dedup)
        out = out.reshape((B, keys.shape[1]) + out.shape[1:])
        if ledgers is not None:
            pending = scratch.device.drain()
            # record layout: (queries, nbytes, waves, deduped_away, overflow)
            overflow = pending[-1][0][4] if pending else 0
            if key_mask is None:
                counts = [int(keys.shape[1])] * B
            elif isinstance(key_mask, jax.Array):
                counts = list(jnp.sum(key_mask, axis=1))  # stays on device
            else:
                counts = [int(c) for c in np.sum(np.asarray(key_mask),
                                                 axis=1)]
            row_bytes = value_bytes or snap._row_bytes
            for ledger, cnt in zip(ledgers, counts):
                if ledger is not None:
                    ledger.record_queries_deferred(
                        cnt, cnt * (row_bytes + 4), waves=1,
                        overflow=overflow)
        return out


class LocalDht(_BackendBase):
    """Gather-based DHT: ``jnp.take`` which XLA partitions under pjit."""

    name = "local"

    def snapshot(self, values, ledger=None,
                 value_bytes: Optional[int] = None) -> ShardedDHT:
        return ShardedDHT(jnp.asarray(values), ledger=ledger,
                          value_bytes=value_bytes)

    def __repr__(self):
        return "LocalDht()"


class RoutedDht(_BackendBase):
    """Explicit router DHT: dedup -> bucket by owner -> all_to_all -> answer.

    This is the collective schedule an RDMA KV store replaces (paper
    Section 5).  ``mesh`` defaults to a 1-D mesh over every visible device;
    pass a production mesh + ``axis_name`` to shard over one of its axes.
    """

    name = "routed"

    def __init__(self, mesh=None, axis_name: Optional[str] = None,
                 capacity: Optional[int] = None):
        if mesh is None:
            devices = jax.devices()
            mesh = jax.make_mesh((len(devices),), ("dht",))
            axis_name = "dht"
        self.mesh = mesh
        self.axis_name = axis_name or mesh.axis_names[0]
        self.capacity = capacity

    def snapshot(self, values, ledger=None,
                 value_bytes: Optional[int] = None) -> ShardedDHT:
        return ShardedDHT(jnp.asarray(values), ledger=ledger,
                          value_bytes=value_bytes, mesh=self.mesh,
                          axis_name=self.axis_name, capacity=self.capacity)

    def __repr__(self):
        return (f"RoutedDht(axis={self.axis_name!r}, "
                f"shards={self.mesh.shape[self.axis_name]})")


def resolve_backend(spec, mesh=None) -> DhtBackend:
    """Map ``"local" | "routed" | DhtBackend-instance`` to a backend object."""
    if isinstance(spec, str):
        if spec == "local":
            return LocalDht()
        if spec == "routed":
            return RoutedDht(mesh=mesh)
        raise ValueError(
            f"unknown dht_backend {spec!r}; expected 'local', 'routed', or a "
            "DhtBackend instance")
    if isinstance(spec, DhtBackend):
        return spec
    raise TypeError(f"dht_backend must be str or DhtBackend, got {type(spec)}")
