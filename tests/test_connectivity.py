"""Connectivity (Theorem 1) + 1-vs-2-cycle (Section 5.6)."""
import numpy as np
import pytest

from repro.graph import generators as gen
from repro.core import connectivity as cc, one_vs_two as ovt, oracle
from repro.core.rounds import RoundLedger


@pytest.mark.parametrize("name,make", [
    ("disjoint", lambda: gen.disjoint_components([50, 80, 120], 3.0, seed=3)),
    ("er_sparse", lambda: gen.erdos_renyi(300, 2.0, seed=5)),
    ("two_cycles", lambda: gen.two_cycles(100)),
    ("grid", lambda: gen.grid2d(10, 30)),
])
def test_cc_ampc_matches_oracle(name, make):
    g = make()
    want = oracle.connected_components(g)
    got, st = cc.cc_ampc(g, seed=1)
    assert np.array_equal(want, got)
    assert st["num_components"] == oracle.num_components(g)


def test_cc_mpc_baseline():
    g = gen.disjoint_components([40, 60], 3.0, seed=9)
    want = oracle.connected_components(g)
    got, st = cc.cc_mpc_hash_to_min(g)
    assert np.array_equal(want, got)
    assert st["phases"] >= 2


def test_cc_shuffles_constant():
    g = gen.erdos_renyi(200, 3.0, seed=2)
    led = RoundLedger("ampc_cc")
    cc.cc_ampc(g, seed=0, ledger=led)
    assert led.shuffles == 5


@pytest.mark.parametrize("k", [100, 400])
def test_one_vs_two_cycle(k):
    one = gen.one_cycle(2 * k)
    two = gen.two_cycles(k)
    n1, _ = ovt.one_vs_two_ampc(one, p=1 / 16, seed=9)
    n2, _ = ovt.one_vs_two_ampc(two, p=1 / 16, seed=9)
    assert (n1, n2) == (1, 2)
    m1, _ = ovt.one_vs_two_mpc(one, seed=9)
    m2, _ = ovt.one_vs_two_mpc(two, seed=9)
    assert (m1, m2) == (1, 2)


def test_one_vs_two_round_separation():
    """AMPC answers in O(1) shuffles; MPC needs Θ(log n) phases."""
    g = gen.two_cycles(500)
    la = RoundLedger("ampc")
    ovt.one_vs_two_ampc(g, p=1 / 16, seed=1, ledger=la)
    lm = RoundLedger("mpc")
    _, st = ovt.one_vs_two_mpc(g, seed=1, ledger=lm)
    assert la.shuffles == 2
    assert lm.shuffles == 3 * st["phases"]
    assert st["phases"] >= np.log2(500) / 2


def test_walk_queries_scale_with_inverse_p():
    g = gen.one_cycle(2000)
    _, st1 = ovt.one_vs_two_ampc(g, p=1 / 8, seed=3)
    # ~n total steps independent of p (every vertex covered ~twice)
    assert st1["walk_steps"] == pytest.approx(2 * 2000, rel=0.3)
