"""The five assigned LM architectures (exact configs from the brief)."""
from __future__ import annotations

from ..models.transformer import TransformerConfig

# [hf:google/gemma-3-1b-pt-family; 5:1 local:global, 128k context]
GEMMA3_12B = TransformerConfig(
    name="gemma3-12b", vocab=262144, n_layers=48, d_model=3840,
    n_heads=16, n_kv_heads=8, head_dim=256, d_ff=15360,
    max_seq_len=131072, sliding_window=1024, local_global_ratio=5,
    qk_norm=True, rope_theta=1_000_000.0, tie_embeddings=True)

# [hf:Qwen/Qwen2.5 family; GQA + QKV bias]
QWEN2_5_32B = TransformerConfig(
    name="qwen2.5-32b", vocab=152064, n_layers=64, d_model=5120,
    n_heads=40, n_kv_heads=8, head_dim=128, d_ff=27648,
    max_seq_len=131072, qkv_bias=True, rope_theta=1_000_000.0)

# [hf:Qwen/Qwen3 family; qk_norm + GQA]
QWEN3_4B = TransformerConfig(
    name="qwen3-4b", vocab=151936, n_layers=36, d_model=2560,
    n_heads=32, n_kv_heads=8, head_dim=128, d_ff=9728,
    max_seq_len=131072, qk_norm=True, rope_theta=1_000_000.0)

# [hf:meta-llama/Llama-4-Scout-17B-16E; MoE 16e top-1 + shared expert]
LLAMA4_SCOUT = TransformerConfig(
    name="llama4-scout-17b-a16e", vocab=202048, n_layers=48, d_model=5120,
    n_heads=40, n_kv_heads=8, head_dim=128, d_ff=8192,
    max_seq_len=131072, moe_experts=16, moe_top_k=1, moe_d_ff=8192,
    moe_shared_expert=True, rope_theta=500_000.0)

# [arXiv:2401.04088; 8 experts top-2, SWA]
MIXTRAL_8X22B = TransformerConfig(
    name="mixtral-8x22b", vocab=32768, n_layers=56, d_model=6144,
    n_heads=48, n_kv_heads=8, head_dim=128, d_ff=16384,
    max_seq_len=65536, sliding_window=4096, moe_experts=8, moe_top_k=2,
    moe_d_ff=16384, rope_theta=1_000_000.0)


def smoke(cfg: TransformerConfig) -> TransformerConfig:
    """Reduced same-family config for CPU smoke tests."""
    import dataclasses
    return dataclasses.replace(
        cfg,
        vocab=512, n_layers=4 if cfg.local_global_ratio == 0 else 6,
        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, max_seq_len=256,
        sliding_window=16 if cfg.sliding_window else 0,
        moe_experts=4 if cfg.moe_experts else 0,
        moe_top_k=min(cfg.moe_top_k, 2) if cfg.moe_experts else 0,
        moe_d_ff=64 if cfg.moe_experts else 0,
        local_global_ratio=2 if cfg.local_global_ratio else 0)
