"""MSF correctness + paper-claim validation (Theorem 1, Lemmas 3.3-3.5)."""
import numpy as np
import pytest

from repro.graph import generators as gen
from repro.core import msf, oracle
from repro.core.rounds import RoundLedger

FAMILIES = [
    ("er", lambda: gen.erdos_renyi(200, 4.0, seed=1).with_random_weights(7)),
    ("rmat", lambda: gen.rmat(9, 6.0, seed=2).with_random_weights(3)),
    ("grid", lambda: gen.grid2d(12, 11).with_random_weights(5)),
    ("two_cycles", lambda: gen.two_cycles(150).with_random_weights(1)),
    ("star", lambda: gen.star(60).with_random_weights(2)),
    ("geo", lambda: gen.random_geometric(100, 1.2, seed=4)[0].with_random_weights(9)),
]


@pytest.mark.parametrize("name,make", FAMILIES)
def test_msf_ampc_matches_kruskal(name, make):
    g = make()
    mask_o, w_o = oracle.kruskal_msf(g)
    mask_a, stats = msf.msf_ampc(g, epsilon=0.5, seed=0,
                                 skip_ternarize_if_dense=False)
    assert np.array_equal(mask_o, mask_a), f"{name}: AMPC MSF != Kruskal"


@pytest.mark.parametrize("name,make", FAMILIES)
def test_msf_mpc_boruvka_matches_kruskal(name, make):
    g = make()
    mask_o, _ = oracle.kruskal_msf(g)
    mask_m, st = msf.msf_mpc_boruvka(g, seed=0)
    assert np.array_equal(mask_o, mask_m)
    assert st["phases"] >= 1


def test_dense_path_used_for_dense_graphs():
    g = gen.erdos_renyi(50, 20.0, seed=0).with_random_weights(1)
    mask, stats = msf.msf_ampc(g, epsilon=0.5, seed=0)
    assert stats["path"] == "dense"
    mask_o, _ = oracle.kruskal_msf(g)
    assert np.array_equal(mask_o, mask)


def test_lemma_3_3_vertex_shrink():
    """Contracted graph has ~n^{eps/2} fewer vertices (Lemma 3.3)."""
    g = gen.rmat(11, 6.0, seed=5).with_random_weights(6)
    _, stats = msf.msf_ampc(g, epsilon=0.5, seed=0,
                            skip_ternarize_if_dense=False)
    expected = stats["n_tern"] ** 0.25  # n^{eps/2} with eps=0.5
    assert stats["shrink_factor"] > expected / 3.0, (
        f"shrink {stats['shrink_factor']:.1f} << n^0.25 = {expected:.1f}")


def test_lemma_3_4_query_complexity():
    """Total Prim queries are O(n log n) w.h.p. (Lemma 3.4)."""
    g = gen.rmat(11, 6.0, seed=7).with_random_weights(8)
    _, stats = msf.msf_ampc(g, epsilon=0.5, seed=0,
                            skip_ternarize_if_dense=False)
    n = stats["n_tern"]
    assert stats["queries"] <= 8 * n * np.log2(n)


def test_round_ledger_shuffle_count():
    """The AMPC MSF implementation uses 5 shuffles (paper Table 3)."""
    g = gen.erdos_renyi(150, 3.0, seed=2).with_random_weights(3)
    led = RoundLedger("ampc_msf")
    msf.msf_ampc(g, seed=0, ledger=led, skip_ternarize_if_dense=False)
    assert led.shuffles == 5
    led2 = RoundLedger("mpc_msf")
    msf.msf_mpc_boruvka(g, seed=0, ledger=led2)
    assert led2.shuffles >= 3 * 5  # 3 shuffles/phase, many phases


def test_degree_weighted_msf():
    """Paper Section 5.2 weight scheme: w(u,v) ~ deg(u)+deg(v)."""
    g = gen.rmat(9, 8.0, seed=5).with_degree_weights()
    mask_o, w_o = oracle.kruskal_msf(g)
    mask_a, _ = msf.msf_ampc(g, seed=0, skip_ternarize_if_dense=False)
    assert abs(float(g.weights[mask_a].sum()) - w_o) < 1e-3


def test_pointer_jump_converges():
    import jax.numpy as jnp
    parent = jnp.asarray(np.array([0, 0, 1, 2, 3, 4], np.int32))
    roots, iters = msf.pointer_jump(parent)
    assert np.all(np.asarray(roots) == 0)
    assert int(iters) <= 4  # log-depth doubling
