"""GNN smoke + property tests: shapes, no NaNs, gradient flow, and exact
E(3)-equivariance of MACE / SchNet rotation invariance."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import REGISTRY
from repro.data import graphs as gdata
from repro.models.gnn import gcn, gin, mace, schnet


def _rotation(seed=0):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((3, 3))
    q, _ = np.linalg.qr(a)
    if np.linalg.det(q) < 0:
        q[:, 0] = -q[:, 0]
    return q.astype(np.float32)


def test_gcn_smoke():
    cfg = REGISTRY["gcn-cora"].smoke_config
    batch = gdata.cora_like(n_nodes=300, d_feat=cfg.d_feat, seed=0)
    params = gcn.init_params(cfg, jax.random.PRNGKey(0))
    logits = gcn.forward(cfg, params, batch)
    assert logits.shape == (batch.n_nodes, cfg.n_classes)
    assert np.isfinite(np.asarray(logits)).all()
    loss, _ = gcn.loss_fn(cfg, params, batch)
    grads = jax.grad(lambda p: gcn.loss_fn(cfg, p, batch)[0])(params)
    assert np.isfinite(float(loss))
    flat, _ = jax.tree.flatten(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in flat)


def test_gin_smoke():
    cfg = REGISTRY["gin-tu"].smoke_config
    batch = gdata.molecules(n_graphs=8, n_atoms=12, seed=1, d_feat=cfg.d_feat)
    import dataclasses
    batch = dataclasses.replace(
        batch, labels=jnp.asarray(np.random.default_rng(0).integers(0, 2, 8)))
    params = gin.init_params(cfg, jax.random.PRNGKey(0))
    logits = gin.forward(cfg, params, batch)
    assert logits.shape == (8, cfg.n_classes)
    loss, _ = gin.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))


def test_schnet_smoke_and_invariance():
    cfg = REGISTRY["schnet"].smoke_config
    batch = gdata.molecules(n_graphs=4, n_atoms=10, seed=2)
    params = schnet.init_params(cfg, jax.random.PRNGKey(0))
    e1 = np.asarray(schnet.forward(cfg, params, batch))
    assert e1.shape == (4,)
    # rotation + translation invariance
    R = _rotation(3)
    import dataclasses
    pos2 = jnp.asarray(np.asarray(batch.positions) @ R.T + 5.0)
    batch2 = dataclasses.replace(batch, positions=pos2)
    e2 = np.asarray(schnet.forward(cfg, params, batch2))
    np.testing.assert_allclose(e1, e2, rtol=1e-4, atol=1e-4)


def test_mace_smoke_equivariance_and_grad():
    cfg = REGISTRY["mace"].smoke_config
    batch = gdata.molecules(n_graphs=4, n_atoms=10, seed=4)
    params = mace.init_params(cfg, jax.random.PRNGKey(0))
    e1 = np.asarray(mace.forward(cfg, params, batch))
    assert e1.shape == (4,) and np.isfinite(e1).all()
    # E(3) invariance of energies (rotation + translation)
    import dataclasses
    for seed in range(3):
        R = _rotation(seed)
        pos2 = jnp.asarray(np.asarray(batch.positions) @ R.T - 2.0)
        e2 = np.asarray(mace.forward(cfg, params,
                                     dataclasses.replace(batch, positions=pos2)))
        np.testing.assert_allclose(e1, e2, rtol=2e-4, atol=2e-4)
    # forces (position gradients) are rotation-equivariant
    def energy_sum(pos):
        return mace.forward(cfg, params,
                            dataclasses.replace(batch, positions=pos)).sum()
    f1 = np.asarray(jax.grad(energy_sum)(batch.positions))
    R = _rotation(7)
    pos_r = jnp.asarray(np.asarray(batch.positions) @ R.T)
    f2 = np.asarray(jax.grad(energy_sum)(pos_r))
    np.testing.assert_allclose(f2, f1 @ R.T, rtol=5e-3, atol=5e-4)


def test_mace_correlation_order_nontrivial():
    """Order-3 B-features change the output (correlation>2 is active)."""
    cfg = REGISTRY["mace"].smoke_config
    batch = gdata.molecules(n_graphs=2, n_atoms=8, seed=5)
    params = mace.init_params(cfg, jax.random.PRNGKey(1))
    e1 = np.asarray(mace.forward(cfg, params, batch))
    p2 = jax.tree.map(lambda x: x, params)
    for lp in p2["layers"]:
        lp["w_b"] = lp["w_b"].at[3:].set(0.0)   # kill order-3 terms
    e2 = np.asarray(mace.forward(cfg, p2, batch))
    assert np.abs(e1 - e2).max() > 1e-7


def test_neighbor_sampler_block():
    from repro.graph import generators as gen
    from repro.data.graphs import NeighborSampler
    g = gen.rmat(10, 8.0, seed=0)
    rng = np.random.default_rng(0)
    feat = rng.standard_normal((g.n, 16)).astype(np.float32)
    labels = rng.integers(0, 5, g.n).astype(np.int32)
    sampler = NeighborSampler(g, fanout=(5, 3), seed=1)
    seeds = rng.integers(0, g.n, 32)
    block = sampler.sample_block(seeds, feat, labels)
    assert block.senders.shape == block.receivers.shape
    assert int(block.node_mask.sum()) == 32 + 32 * 5 + 32 * 5 * 3
    # every edge receiver is in an earlier layer than its sender
    assert int(block.receivers.max()) < 32 + 32 * 5
    # features of seed rows match
    np.testing.assert_allclose(np.asarray(block.node_feat[:32]), feat[seeds])
