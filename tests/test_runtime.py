"""Fault tolerance: checkpoint/restart, preemption, elastic re-shard,
straggler dispatch, gradient compression."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import checkpointer as ckpt
from repro.runtime.fault_tolerance import (RunnerConfig, StragglerDispatcher,
                                           TrainRunner)
from repro.optim import grad_compression as gc


def _toy_state():
    return {"w": jnp.zeros((4, 4)), "step_sum": jnp.zeros(())}


def _toy_step(state, step):
    return {"w": state["w"] + 1.0, "step_sum": state["step_sum"] + step}


def test_checkpoint_roundtrip(tmp_path):
    state = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    ckpt.save(str(tmp_path), 7, state)
    got, step = ckpt.restore(str(tmp_path), state)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(state["a"]))
    np.testing.assert_array_equal(np.asarray(got["b"]["c"]), np.ones(4))


def test_keep_n_cleanup(tmp_path):
    state = _toy_state()
    for s in range(6):
        ckpt.save(str(tmp_path), s, state, keep=2)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2 and steps[-1] == "step_00000005"


def test_preemption_restart_equivalence(tmp_path):
    """Kill at step 7, restart: final state identical to an uninterrupted run."""
    cfg = RunnerConfig(str(tmp_path / "a"), ckpt_every=3, max_steps=12)
    r = TrainRunner(cfg, _toy_state, _toy_step)
    with pytest.raises(RuntimeError, match="simulated preemption"):
        r.run(crash_at_step=7)
    state_resumed = TrainRunner(cfg, _toy_state, _toy_step).run()
    cfg2 = RunnerConfig(str(tmp_path / "b"), ckpt_every=3, max_steps=12)
    state_clean = TrainRunner(cfg2, _toy_state, _toy_step).run()
    np.testing.assert_allclose(np.asarray(state_resumed["w"]),
                               np.asarray(state_clean["w"]))
    np.testing.assert_allclose(np.asarray(state_resumed["step_sum"]),
                               np.asarray(state_clean["step_sum"]))


def test_elastic_restore_across_meshes(tmp_path):
    """Save on a 4-device mesh, restore sharded onto an 8-device mesh."""
    script = textwrap.dedent("""
        import os, sys
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import checkpointer as ckpt
        mesh = jax.make_mesh((%d,), ("data",))
        sh = NamedSharding(mesh, P("data"))
        state = {"w": jax.device_put(jnp.arange(32.0), sh)}
        mode = sys.argv[1]
        if mode == "save":
            ckpt.save(%r, 3, state)
        else:
            got, step = ckpt.restore(%r, state, shardings={"w": sh})
            assert step == 3
            assert np.allclose(np.asarray(got["w"]), np.arange(32.0))
            assert len(got["w"].sharding.device_set) == %d
        print("OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    d = str(tmp_path)
    r1 = subprocess.run([sys.executable, "-c",
                         script % (4, 4, d, d, 4), "save"],
                        env=env, capture_output=True, text=True, timeout=600)
    assert r1.returncode == 0, r1.stderr[-1500:]
    r2 = subprocess.run([sys.executable, "-c",
                         script % (8, 8, d, d, 8), "load"],
                        env=env, capture_output=True, text=True, timeout=600)
    assert r2.returncode == 0, r2.stderr[-1500:]


def test_straggler_dispatch_reissues_and_completes():
    disp = StragglerDispatcher(n_chunks=8, n_workers=4, deadline_s=1.0)
    t = 0.0
    # workers 0..3 each take a chunk; worker 3 is a straggler (never finishes)
    taken = {w: disp.assign(w, now=t) for w in range(4)}
    for w in range(3):
        assert disp.complete(taken[w])
    # time passes beyond the deadline; idle workers pick up remaining chunks
    t = 2.0
    done = set(disp.completed)
    while True:
        c = disp.assign(0, now=t)
        if c is None:
            break
        assert disp.complete(c)
    assert disp.reissues >= 1                  # straggler's chunk re-issued
    assert len(disp.completed) == 8            # every chunk completed
    # duplicate completion is deduped
    assert not disp.complete(taken[0])


def test_grad_compression_error_feedback_converges():
    """EF keeps the quantized optimizer convergent on a quadratic."""
    w_true = jnp.asarray(np.random.default_rng(0).standard_normal(64))
    w = jnp.zeros(64)
    fb = jnp.zeros(64)
    for _ in range(300):
        g = w - w_true                          # grad of 0.5||w - w*||^2
        q, s, fb = gc.compress(g, fb)
        w = w - 0.1 * gc.decompress(q, s)
    assert float(jnp.abs(w - w_true).max()) < 1e-2


def test_grad_compression_bias_bounded():
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal(1000).astype(np.float32))
    q, s, fb = gc.compress(g, jnp.zeros(1000))
    rec = gc.decompress(q, s)
    # quantization error bounded by scale/2 per element
    assert float(jnp.abs(rec + fb - g).max()) < 1e-6  # exact identity w/ fb
    assert float(jnp.abs(rec - g).max()) <= float(s) * 0.5 + 1e-6


def test_train_cli_resume(tmp_path):
    """The train driver resumes deterministically (loss curve continuous)."""
    from repro.launch.train import train_lm
    d = str(tmp_path / "ck")
    losses_a = train_lm("qwen3-4b", True, 6, d, batch=2, seq_len=16,
                        ckpt_every=3, log_every=100)
    losses_b = train_lm("qwen3-4b", True, 10, d, batch=2, seq_len=16,
                        ckpt_every=3, log_every=100)
    full = train_lm("qwen3-4b", True, 10, "", batch=2, seq_len=16,
                    log_every=100)
    assert len(losses_b) == 10 - 6             # resumed from step 6
    np.testing.assert_allclose(losses_b, full[6:], rtol=2e-3, atol=2e-3)
