"""Mixture-of-experts layer with sort-based (dropping) token dispatch.

Dispatch strategy: tokens are routed top-k, sorted by expert id, and
scattered into an (E, C, d) buffer (capacity C = ceil(T*k/E * capacity
factor)); overflow tokens are dropped (their combine weight contributes 0,
residual passes through).  This compiles to gather/scatter + one grouped
einsum — O(T·d) memory instead of the O(T·E·C) one-hot dispatch tensor, which
matters at dry-run scale (1M tokens × 16 experts).

Routing: softmax router, top-k, renormalized combine weights (Mixtral
convention), Switch-style load-balancing auxiliary loss.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class MoeSpec:
    d_model: int
    d_ff: int
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    shared_expert: bool = False   # llama4-style shared expert alongside routed


def init_moe(key, spec: MoeSpec, dtype=jnp.float32):
    d, f, E = spec.d_model, spec.d_ff, spec.n_experts
    ks = jax.random.split(key, 5)
    s_in, s_out = 1.0 / np.sqrt(d), 1.0 / np.sqrt(f)
    p = {
        "router": jax.random.normal(ks[0], (d, E), dtype) * s_in,
        "w_gate": jax.random.normal(ks[1], (E, d, f), dtype) * s_in,
        "w_up": jax.random.normal(ks[2], (E, d, f), dtype) * s_in,
        "w_down": jax.random.normal(ks[3], (E, f, d), dtype) * s_out,
    }
    if spec.shared_expert:
        from .layers import init_mlp
        p["shared"] = init_mlp(ks[4], d, f, dtype)
    return p


def moe_apply_local(params, x, spec: MoeSpec, dp_shards: int,
                    token_cs=None, buf_cs=None, hid_cs=None
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Locality-aware dispatch (EXPERIMENTS.md §Perf, mixtral hillclimb):
    tokens are logically reshaped to (dp_shards, T_local, d) and routed /
    dispatched *within each shard* — every gather/scatter of the dispatch
    stays device-local under GSPMD; the only cross-device traffic is the
    (small) FSDP all-gather of the expert weights.  Capacity is per-shard
    (standard in EP systems); same routing, per-shard drop pattern."""
    B, S, d = x.shape
    T = B * S
    E, K = spec.n_experts, spec.top_k
    assert T % dp_shards == 0
    Tl = T // dp_shards
    xt = x.reshape(dp_shards, Tl, d)
    if token_cs is not None:
        xt = token_cs(xt)
    C = int(np.ceil(Tl * K / E * spec.capacity_factor))

    def shard_dispatch(xl):
        logits = (xl @ params["router"].astype(xl.dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, gate_idx = jax.lax.top_k(probs, K)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)
        me = probs.mean(axis=0)
        ce = jnp.zeros((E,), jnp.float32).at[gate_idx.reshape(-1)].add(
            1.0 / (Tl * K))
        aux = E * jnp.sum(me * ce)
        A = Tl * K
        slot_expert = gate_idx.reshape(-1)
        slot_token = jnp.repeat(jnp.arange(Tl, dtype=jnp.int32), K)
        slot_gate = gate_vals.reshape(-1)
        order = jnp.argsort(slot_expert)
        se, stok, sg = slot_expert[order], slot_token[order], slot_gate[order]
        start = jnp.searchsorted(se, jnp.arange(E, dtype=jnp.int32))
        rank = jnp.arange(A, dtype=jnp.int32) - start[jnp.clip(se, 0, E - 1)]
        keep = rank < C
        buf_pos = jnp.where(keep, se * C + rank, E * C)
        buf = jnp.zeros((E * C + 1, d), xl.dtype).at[buf_pos].set(
            xl[stok], mode="drop")
        return (buf[:-1].reshape(E, C, d), buf_pos, stok, sg, keep, aux)

    buf, buf_pos, stok, sg, keep, aux = jax.vmap(shard_dispatch)(xt)
    # buf: (dp, E, C, d) — experts run on every shard's local capacity.
    # Megatron-style TP: hidden (f) sharded over model => column-parallel
    # w_gate/w_up (local), row-parallel w_down (one AR of the output).
    if buf_cs is not None:
        buf = buf_cs(buf)
    g = jax.nn.silu(jnp.einsum("secd,edf->secf", buf,
                               params["w_gate"].astype(x.dtype)))
    if hid_cs is not None:
        g = hid_cs(g)
    u = jnp.einsum("secd,edf->secf", buf, params["w_up"].astype(x.dtype))
    if hid_cs is not None:
        u = hid_cs(u)
    y = jnp.einsum("secf,efd->secd", g * u, params["w_down"].astype(x.dtype))
    if buf_cs is not None:
        y = buf_cs(y)
    y = y.reshape(dp_shards, E * C, d)

    def shard_combine(yl, buf_pos_l, stok_l, sg_l, keep_l):
        contrib = jnp.where(
            keep_l[:, None],
            yl[jnp.clip(buf_pos_l, 0, E * C - 1)]
            * sg_l[:, None].astype(x.dtype), 0)
        return jnp.zeros((Tl, d), x.dtype).at[stok_l].add(contrib)

    out = jax.vmap(shard_combine)(y, buf_pos, stok, sg, keep)
    if token_cs is not None:
        out = token_cs(out)
    out = out.reshape(B, S, d)
    if spec.shared_expert:
        from .layers import mlp_swiglu
        out = out + mlp_swiglu(params["shared"], x)
    return out, aux.mean()


def moe_apply(params, x, spec: MoeSpec, token_cs=None, buf_cs=None,
              y_cs=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out, aux_loss).
    token_cs: sharding constraint for (T, d) token tensors.
    buf_cs/y_cs: constraints for the (E, C, d)/(E, C, f) dispatch buffers —
    keeping capacity token-sharded forces GSPMD to all-gather the (small,
    FSDP-sharded) expert weights instead of all-reducing the (huge)
    activations (§Perf, mixtral hillclimb)."""
    B, S, d = x.shape
    T = B * S
    E, K = spec.n_experts, spec.top_k
    xt = x.reshape(T, d)
    if token_cs is not None:
        xt = token_cs(xt)
    logits = (xt @ params["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)                # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- aux load-balancing loss (Switch): E * sum_e f_e * P_e
    me = probs.mean(axis=0)                                      # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[gate_idx.reshape(-1)].add(
        1.0 / (T * K))
    aux = E * jnp.sum(me * ce)

    # ---- sort-based dispatch over T*K assignment slots
    A = T * K
    C = int(np.ceil(A / E * spec.capacity_factor))
    slot_expert = gate_idx.reshape(-1)                           # (A,)
    slot_token = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    slot_gate = gate_vals.reshape(-1)
    order = jnp.argsort(slot_expert)
    se, stok, sg = slot_expert[order], slot_token[order], slot_gate[order]
    # rank within expert
    start = jnp.searchsorted(se, jnp.arange(E, dtype=jnp.int32))
    rank = jnp.arange(A, dtype=jnp.int32) - start[jnp.clip(se, 0, E - 1)]
    keep = rank < C
    buf_pos = jnp.where(keep, se * C + rank, E * C)              # OOB -> drop
    # gather token features into (E*C, d)
    buf = jnp.zeros((E * C + 1, d), x.dtype).at[buf_pos].set(
        xt[stok], mode="drop")
    buf = buf[:-1].reshape(E, C, d)
    if buf_cs is not None:
        buf = buf_cs(buf)

    # ---- expert FFN (grouped einsum)
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf,
                               params["w_gate"].astype(x.dtype)))
    if y_cs is not None:
        g = y_cs(g)
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(x.dtype))
    if y_cs is not None:
        u = y_cs(u)
    y = jnp.einsum("ecf,efd->ecd", g * u, params["w_down"].astype(x.dtype))
    if buf_cs is not None:
        y = buf_cs(y)
    y = y.reshape(E * C, d)

    # ---- combine back (scatter-add weighted outputs per token)
    contrib = jnp.where(keep[:, None], y[jnp.clip(buf_pos, 0, E * C - 1)]
                        * sg[:, None].astype(x.dtype), 0)
    out = jnp.zeros((T, d), x.dtype).at[stok].add(contrib)
    if token_cs is not None:
        out = token_cs(out)
    if spec.shared_expert:
        from .layers import mlp_swiglu
        out = out + mlp_swiglu(params["shared"], xt)
    return out.reshape(B, S, d), aux
