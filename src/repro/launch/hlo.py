"""Post-SPMD HLO static analysis: FLOPs, HBM bytes, collective wire bytes.

``compiled.cost_analysis()`` counts each while-loop body ONCE (scan bodies are
not multiplied by trip count), which under-reports a scanned transformer by
~L×.  We therefore walk the HLO text ourselves:

  * parse every computation + instruction (shape table);
  * dot FLOPs = 2 · numel(result) · contracted-size (from operand shapes);
  * HBM bytes  = Σ (operand+result bytes) over materializing ops;
  * collective wire bytes via ring formulas with replica-group size;
  * while bodies multiply by ``known_trip_count`` from backend_config
    (conditionals count each branch once).

Hardware model (TPU v5e-class, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "while", "conditional", "call", "partition-id",
    "replica-id", "rng-get-and-update-state",
}
_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute"}


def _shape_info(type_str: str) -> Tuple[int, List[Tuple[str, List[int]]]]:
    """bytes + [(dtype, dims), ...] for a (possibly tuple) HLO type."""
    shapes = []
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        dl = [int(d) for d in dims.split(",") if d] if dims else []
        n = 1
        for d in dl:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        shapes.append((dt, dl))
    return total, shapes


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    attrs: str
    raw_operands: str = ""


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]


def _match_paren(s: str, start: int) -> int:
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i
    return len(s) - 1


def _parse_instr(line: str) -> Optional[Instr]:
    line = line.strip()
    if line.startswith("ROOT "):
        line = line[5:]
    m = re.match(r"%?([\w\.\-]+)\s*=\s*", line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):
        close = _match_paren(rest, 0)
        type_str = rest[:close + 1]
        rest = rest[close + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str = rest[:sp]
        rest = rest[sp + 1:].lstrip()
    om = re.match(r"([\w\-]+)\(", rest)
    if not om:
        return None
    opcode = om.group(1)
    close = _match_paren(rest, om.end() - 1)
    opnds_str = rest[om.end():close]
    attrs = rest[close + 1:]
    operands = re.findall(r"%([\w\.\-]+)", opnds_str)
    return Instr(name, type_str, opcode, operands, attrs, opnds_str)


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        hm = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$", line)
        if hm and not line.startswith(" "):
            cur = Computation(hm.group(2), [])
            comps[cur.name] = cur
            if hm.group(1):
                comps["__entry__"] = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None and "=" in line:
            ins = _parse_instr(line)
            if ins:
                cur.instrs.append(ins)
    return comps


@dataclasses.dataclass
class CollectiveStats:
    ops: Dict[str, int]
    wire_bytes: float
    payload_bytes: float
    details: List[dict] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class HloAnalysis:
    flops: float
    hbm_bytes: float
    collectives: CollectiveStats
    dot_flops_by_comp: Dict[str, float] = dataclasses.field(default_factory=dict)
    unknown_trip_whiles: int = 0
    coll_by_site: Dict[str, float] = dataclasses.field(default_factory=dict)
    bytes_by_op: Dict[str, float] = dataclasses.field(default_factory=dict)

    def top_collective_sites(self, k=12):
        return sorted(self.coll_by_site.items(), key=lambda kv: -kv[1])[:k]

    def top_byte_ops(self, k=12):
        return sorted(self.bytes_by_op.items(), key=lambda kv: -kv[1])[:k]


def _group_size(attrs: str) -> int:
    gm = re.search(r"replica_groups=\{\{([^}]*)\}", attrs)
    if gm:
        return len(gm.group(1).split(","))
    gi = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if gi:
        return int(gi.group(2))
    return 2


def _collective_wire(kind: str, nbytes: int, p: int) -> float:
    frac = (p - 1) / p
    if kind == "all-gather":
        return nbytes * frac
    if kind == "all-reduce":
        return 2 * nbytes * frac
    if kind == "reduce-scatter":
        return nbytes * (p - 1)
    if kind == "all-to-all":
        return nbytes * frac
    return float(nbytes)  # collective-permute


def analyze_hlo(text: str) -> HloAnalysis:
    comps = parse_hlo(text)
    if "__entry__" not in comps:
        raise ValueError("no ENTRY computation found")
    # global shape table
    shapes: Dict[str, Tuple[int, List[Tuple[str, List[int]]]]] = {}
    for comp in comps.values():
        for ins in comp.instrs:
            shapes[ins.name] = _shape_info(ins.type_str)

    coll = CollectiveStats({}, 0.0, 0.0)
    total = {"flops": 0.0, "bytes": 0.0, "unknown_whiles": 0}
    dot_by_comp: Dict[str, float] = {}
    coll_by_site: Dict[str, float] = {}
    bytes_by_op: Dict[str, float] = {}
    visiting = set()

    # --- effective read size of a fusion operand: if (inside the fused
    # computation) the parameter only feeds dynamic-slice/gather, the real
    # read is the slice size, not the full (e.g. layer-stacked) array.
    _param_reads_cache: Dict[Tuple[str, int], float] = {}

    def _fusion_operand_read(comp_name: str, param_idx: int,
                             full_bytes: int) -> float:
        key = (comp_name, param_idx)
        if key not in _param_reads_cache:
            _param_reads_cache[key] = _compute_param_read(comp_name, param_idx)
        r = _param_reads_cache[key]
        return full_bytes if r < 0 else min(r, full_bytes)

    def _compute_param_read(comp_name: str, param_idx: int) -> float:
        """Bytes actually read of parameter `param_idx`; -1 => full."""
        comp = comps.get(comp_name)
        if comp is None:
            return -1.0
        users: Dict[str, List[Instr]] = {}
        params: Dict[int, str] = {}
        for ins in comp.instrs:
            if ins.opcode == "parameter":
                pm = re.match(r"\s*(\d+)", ins.raw_operands)
                if pm:
                    params[int(pm.group(1))] = ins.name
            for o in ins.operands:
                users.setdefault(o, []).append(ins)
        pname = params.get(param_idx)
        if pname is None:
            return -1.0
        consumers = users.get(pname, [])
        if consumers and all(c.opcode in ("dynamic-slice", "gather", "slice")
                             for c in consumers):
            return float(sum(shapes.get(c.name, (0, []))[0]
                             for c in consumers))
        return -1.0

    def comp_cost(comp_name: str, mult: float, count_bytes: bool = True):
        if comp_name not in comps or comp_name in visiting:
            return
        visiting.add(comp_name)
        comp = comps[comp_name]
        for ins in comp.instrs:
            op = ins.opcode
            rbytes, rshapes = shapes.get(ins.name, (0, []))
            # --- FLOPs: dot ops
            if op == "dot":
                numel = 1
                if rshapes:
                    for d in rshapes[0][1]:
                        numel *= d
                cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}",
                                  ins.attrs)
                csize = 1
                if cdims and ins.operands:
                    lhs = shapes.get(ins.operands[0])
                    if lhs and lhs[1]:
                        ldims = lhs[1][0][1]
                        for di in cdims.group(1).split(","):
                            if di and int(di) < len(ldims):
                                csize *= ldims[int(di)]
                f = 2.0 * numel * csize * mult
                total["flops"] += f
                dot_by_comp[comp_name] = dot_by_comp.get(comp_name, 0.0) + f
            # --- collectives
            if op in _COLLECTIVES or any(op == c + "-start" for c in _COLLECTIVES):
                kind = op.replace("-start", "")
                p = _group_size(ins.attrs)
                w = _collective_wire(kind, rbytes, p) * mult
                coll.ops[kind] = coll.ops.get(kind, 0) + int(mult)
                coll.wire_bytes += w
                coll.payload_bytes += rbytes * mult
                om = re.search(r'op_name="([^"]+)"', ins.attrs)
                site = (om.group(1)[-70:] if om else comp_name[-40:])
                site = f"{kind}:{site}"
                coll_by_site[site] = coll_by_site.get(site, 0.0) + w
            # --- HBM bytes (slice-aware read model; fusion internals are
            # VMEM/register traffic, not HBM)
            if count_bytes and op not in _SKIP_BYTES_OPS \
                    and not op.endswith("-done"):
                if op in ("dynamic-slice", "gather", "slice"):
                    total["bytes"] += 2.0 * rbytes * mult
                    bytes_by_op[op] = bytes_by_op.get(op, 0.0) + 2.0 * rbytes * mult
                elif op == "dynamic-update-slice" and len(ins.operands) >= 2:
                    upd = shapes.get(ins.operands[1], (0, []))[0]
                    total["bytes"] += 2.0 * upd * mult
                    bytes_by_op[op] = bytes_by_op.get(op, 0.0) + 2.0 * upd * mult
                elif op == "fusion":
                    fc = re.search(r"calls=%([\w\.\-]+)", ins.attrs)
                    ob = 0.0
                    for i, o in enumerate(ins.operands):
                        fb = shapes.get(o, (0, []))[0]
                        ob += (_fusion_operand_read(fc.group(1), i, fb)
                               if fc else fb)
                    total["bytes"] += (rbytes + ob) * mult
                    bytes_by_op["fusion"] = bytes_by_op.get("fusion", 0.0) + (rbytes + ob) * mult
                else:
                    ob = sum(shapes.get(o, (0, []))[0] for o in ins.operands)
                    total["bytes"] += (rbytes + ob) * mult
                    bytes_by_op[op] = bytes_by_op.get(op, 0.0) + (rbytes + ob) * mult
            # --- recurse into called computations
            if op == "while":
                tc = re.search(r'known_trip_count[":{\s]*n["\s:]*"?(\d+)',
                               ins.attrs)
                trip = int(tc.group(1)) if tc else 1
                if not tc:
                    total["unknown_whiles"] += 1
                body = re.search(r"body=%([\w\.\-]+)", ins.attrs)
                cond = re.search(r"condition=%([\w\.\-]+)", ins.attrs)
                if body:
                    comp_cost(body.group(1), mult * trip, count_bytes)
                if cond:
                    comp_cost(cond.group(1), mult * trip, count_bytes)
            elif op in ("fusion", "call", "map", "reduce", "reduce-window",
                        "sort", "scatter", "select-and-scatter"):
                inner_bytes = count_bytes and op == "call"
                for cm in re.finditer(r"(?:calls|to_apply)=%([\w\.\-]+)",
                                      ins.attrs):
                    comp_cost(cm.group(1), mult, inner_bytes)
            elif op == "conditional":
                for cm in re.finditer(r"%([\w\.\-]+)", ins.attrs):
                    if cm.group(1) in comps:
                        comp_cost(cm.group(1), mult, count_bytes)
        visiting.discard(comp_name)

    comp_cost(comps["__entry__"].name, 1.0)
    return HloAnalysis(total["flops"], total["bytes"], coll, dot_by_comp,
                       total["unknown_whiles"], coll_by_site, bytes_by_op)


def roofline_terms(analysis: HloAnalysis, chips: int,
                   model_flops: float) -> dict:
    """Three roofline terms in seconds (per-device program quantities over
    per-chip hardware rates)."""
    t_compute = analysis.flops / PEAK_FLOPS
    t_memory = analysis.hbm_bytes / HBM_BW
    t_collective = analysis.collectives.wire_bytes / ICI_BW
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_collective), key=lambda kv: kv[1])[0]
    bound = max(t_compute, t_memory, t_collective)
    ideal = model_flops / chips / PEAK_FLOPS
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dominant,
        "hlo_flops_per_device": analysis.flops,
        "hlo_bytes_per_device": analysis.hbm_bytes,
        "coll_wire_bytes_per_device": analysis.collectives.wire_bytes,
        "model_flops": model_flops,
        "useful_flops_fraction": model_flops / max(analysis.flops * chips, 1.0),
        "roofline_fraction": ideal / bound if bound > 0 else 0.0,
        "collective_ops": analysis.collectives.ops,
        "unknown_trip_whiles": analysis.unknown_trip_whiles,
    }
