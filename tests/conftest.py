"""Test-suite conftest: deterministic fallback for ``hypothesis``.

The property tests use a small slice of the hypothesis API
(``given`` / ``settings`` / ``strategies.integers|floats|lists|data``).
When the real package is unavailable (this container does not ship it), we
register a minimal deterministic stand-in under ``sys.modules`` so the four
property-test modules still collect and run: each ``@given`` test executes
``max_examples`` times with seeded numpy randomness instead of being
skipped wholesale.  With hypothesis installed this file is a no-op.
"""
from __future__ import annotations


import sys
import types

try:  # pragma: no cover - exercised only when hypothesis exists
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import numpy as np

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    def integers(lo, hi):
        return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

    def floats(lo, hi):
        return _Strategy(lambda rng: float(rng.uniform(lo, hi)))

    def lists(elem, min_size=0, max_size=10):
        def sample(rng):
            k = int(rng.integers(min_size, max_size + 1))
            return [elem.sample(rng) for _ in range(k)]
        return _Strategy(sample)

    class _Data:
        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy):
            return strategy.sample(self._rng)

    def data():
        return _Strategy(lambda rng: _Data(rng))

    def given(*strategies):
        def deco(fn):
            # zero-arg wrapper: pytest must not mistake drawn args for fixtures
            def wrapper():
                for i in range(wrapper._max_examples):
                    rng = np.random.default_rng(i)
                    fn(*[s.sample(rng) for s in strategies])
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            # honor @settings whether it wraps @given or sits under it
            wrapper._max_examples = getattr(fn, "_max_examples", 10)
            return wrapper
        return deco

    def settings(max_examples=10, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.floats = floats
    st_mod.lists = lists
    st_mod.data = data

    hyp_mod = types.ModuleType("hypothesis")
    hyp_mod.given = given
    hyp_mod.settings = settings
    hyp_mod.strategies = st_mod
    hyp_mod.__is_fallback__ = True

    sys.modules["hypothesis"] = hyp_mod
    sys.modules["hypothesis.strategies"] = st_mod
