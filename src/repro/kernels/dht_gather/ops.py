"""jit wrapper with impl switch for dht_gather (cached gather)."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import dht_gather_pallas
from .ref import dht_gather_ref


def dht_gather(table, keys, impl: str = "pallas", interpret: bool = True,
               block_q: int = 64, presorted: bool = False):
    """Gather table rows for a key batch with the caching optimization.
    Returns (out, cache_hits_total)."""
    if not presorted:
        order = jnp.argsort(keys)
        sk = keys[order]
    else:
        order = None
        sk = keys
    if impl == "pallas":
        out, hits = dht_gather_pallas(table, sk, block_q=block_q,
                                      interpret=interpret)
        total_hits = hits.sum()
    else:
        out = dht_gather_ref(table, sk)
        total_hits = (sk[1:] == sk[:-1]).sum()
    if order is not None:
        inv = jnp.zeros_like(order).at[order].set(
            jnp.arange(order.shape[0], dtype=order.dtype))
        out = out[inv]
    return out, total_hits
