"""Benchmark harness entry point:  PYTHONPATH=src python -m benchmarks.run

Runs one benchmark per paper table/figure and the roofline report, all
dispatched through ``benchmarks.registry`` (each module self-registers with
``@bench``).  Shared config path:

  --only <name>     run a single benchmark
  --quick           registry-declared reduced settings per benchmark
  --graphs a,b,c    graph subset (names from benchmarks.common.GRAPHS) for
                    every benchmark that takes graphs; overrides --quick's
                    default subset
  --trace out.json  record the whole run as a Chrome trace (open in
                    chrome://tracing or https://ui.perfetto.dev): one
                    ``bench:<name>`` span per benchmark, engine solves
                    nested inside.  Also prints the metrics report.
"""
from __future__ import annotations

import argparse
import sys
import time

from repro.obs import (Tracer, coverage, metrics_report, set_default_tracer,
                       write_chrome_trace)
from repro.obs.metrics import default_registry

from . import registry
from .common import GRAPHS


def main(argv=None):
    names = registry.names()
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=names)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--graphs",
                    help="comma-separated subset of "
                         f"{sorted(GRAPHS)} for graph benchmarks")
    ap.add_argument("--trace", metavar="OUT.json",
                    help="write a Chrome/Perfetto trace of the run")
    args = ap.parse_args(argv)
    graph_names = None
    if args.graphs:
        graph_names = [g.strip() for g in args.graphs.split(",") if g.strip()]
        unknown = [g for g in graph_names if g not in GRAPHS]
        if unknown:
            ap.error(f"unknown graphs {unknown}; known: {sorted(GRAPHS)}")
    selected = [args.only] if args.only else names
    tracer = None
    if args.trace:
        # engines created with trace=None inside the benchmarks inherit
        # this tracer, so their solve spans nest under bench:<name>
        tracer = Tracer()
        set_default_tracer(tracer)
    results = {}
    wall0 = time.perf_counter()
    try:
        for name in selected:
            spec = registry.get(name)
            print(f"\n{'='*72}\n== {name}\n{'='*72}", flush=True)
            t0 = time.time()
            kw = dict(spec.quick_kwargs) if args.quick else {}
            if spec.takes_graphs and graph_names is not None:
                kw["graph_names"] = graph_names
            try:
                if tracer is not None:
                    with tracer.span(f"bench:{name}"):
                        results[name] = spec.fn(**kw)
                else:
                    results[name] = spec.fn(**kw)
                print(f"[{name} done in {time.time()-t0:.1f}s]")
            except Exception as e:  # noqa: BLE001
                print(f"[{name} FAILED: {e}]")
                results[name] = {"error": str(e)}
    finally:
        if tracer is not None:
            set_default_tracer(None)
    if tracer is not None:
        wall_us = (time.perf_counter() - wall0) * 1e6
        doc = write_chrome_trace(args.trace, tracer, extra_meta={
            "measured_wall_us": int(wall_us),
            "benchmarks": list(results)})
        cov = coverage(tracer, wall_us)
        print(f"\ntrace: {len(doc['traceEvents'])} events -> {args.trace} "
              f"(span coverage {100 * cov:.1f}% of {wall_us / 1e6:.1f}s wall)")
        print(f"\n{'='*72}\n== metrics\n{'='*72}")
        print(metrics_report(default_registry()))
    failed = [k for k, v in results.items() if "error" in v]
    print(f"\n{'='*72}\n{len(selected)-len(failed)}/{len(selected)} "
          f"benchmarks succeeded" + (f"; FAILED: {failed}" if failed else ""))
    return 0 if not failed else 1


if __name__ == "__main__":
    sys.exit(main())
