"""Pallas TPU kernel: cached gather — the paper's caching optimization as a
VMEM-resident reuse rule.

The caller sorts the key batch (as the DHT router does before bucketing);
inside a block the kernel walks keys sequentially and issues an HBM row DMA
*only when the key differs from the previous one* — adjacent duplicates hit
the in-register "cache", exactly the per-machine memoization of Section 5.3.
The skipped-load count is returned so benchmarks can report cache savings.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dht_gather_kernel(keys_ref, table_ref, o_ref, hits_ref, *, bq: int):
    i = pl.program_id(0)
    D = table_ref.shape[1]

    def step(r, carry):
        prev_key, prev_row, hits = carry
        idx = keys_ref[i * bq + r]
        same = idx == prev_key
        valid = idx >= 0
        safe = jnp.maximum(idx, 0)

        def load(_):
            return pl.load(table_ref, (pl.ds(safe, 1), slice(None))
                           ).astype(jnp.float32)

        row = jax.lax.cond(same, lambda _: prev_row, load, None)
        out = jnp.where(valid, row, 0.0)
        o_ref[r, :] = out[0].astype(o_ref.dtype)
        hits = hits + jnp.where(same & valid, 1, 0)
        return idx, row, hits

    prev = (jnp.int32(-2), jnp.zeros((1, D), jnp.float32), jnp.int32(0))
    _, _, hits = jax.lax.fori_loop(0, bq, step, prev)
    hits_ref[0] = hits


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def dht_gather_pallas(table, sorted_keys, block_q: int = 64,
                      interpret: bool = True):
    """table: (V, D); sorted_keys: (Q,) ascending (-1 pad).
    Returns (out (Q, D), cache_hits (Q//bq,))."""
    V, D = table.shape
    Q = sorted_keys.shape[0]
    bq = min(block_q, Q)
    assert Q % bq == 0
    kernel = functools.partial(_dht_gather_kernel, bq=bq)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(Q // bq,),
            in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
            out_specs=[
                pl.BlockSpec((bq, D), lambda i, keys: (i, 0)),
                pl.BlockSpec((1,), lambda i, keys: (i,)),
            ],
        ),
        out_shape=[jax.ShapeDtypeStruct((Q, D), table.dtype),
                   jax.ShapeDtypeStruct((Q // bq,), jnp.int32)],
        interpret=interpret,
    )(sorted_keys, table)
