"""End-to-end training driver: a ~100M-parameter qwen3-family LM trained on
the synthetic token stream with checkpointing (deliverable b).

  PYTHONPATH=src python examples/train_lm.py --steps 300         # full
  PYTHONPATH=src python examples/train_lm.py --steps 20 --tiny   # smoke

The 100M configuration is a scaled qwen3 (same qk-norm/GQA family):
d_model=640, 10 layers, vocab 32k  ->  ~103M params.
"""
import argparse
import dataclasses
import functools
import time

import jax
import jax.numpy as jnp

from repro.configs.lm_archs import QWEN3_4B
from repro.data.tokens import TokenStreamConfig, batch_at_step
from repro.models import transformer as tr
from repro.optim import adamw
from repro.checkpoint import checkpointer as ckpt
from repro.launch import steps


def config_100m():
    return dataclasses.replace(
        QWEN3_4B, name="qwen3-100m", vocab=32768, n_layers=10, d_model=640,
        n_heads=8, n_kv_heads=4, head_dim=64, d_ff=2048, max_seq_len=1024)


def config_tiny():
    return dataclasses.replace(
        QWEN3_4B, name="qwen3-tiny", vocab=1024, n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, max_seq_len=256)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = config_tiny() if args.tiny else config_100m()
    n_params = cfg.param_count()
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params")

    opt_cfg = adamw.AdamWConfig(lr=3e-4, warmup_steps=30,
                                total_steps=max(args.steps, 100))
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = adamw.init_state(params)
    state = {"params": params, "opt": opt_state}
    start = 0
    if ckpt.latest_step(args.ckpt_dir) is not None:
        state, last = ckpt.restore(args.ckpt_dir, state)
        start = last + 1
        print(f"resumed from step {last}")

    step_fn = jax.jit(functools.partial(steps.lm_train_step, cfg, opt_cfg))
    stream = TokenStreamConfig(cfg.vocab, args.seq_len, args.batch, seed=0)
    t0 = time.time()
    first = last_loss = None
    for step in range(start, args.steps):
        tokens, labels = batch_at_step(stream, step)
        p, o, m = step_fn(state["params"], state["opt"],
                          jnp.asarray(tokens), jnp.asarray(labels))
        state = {"params": p, "opt": o}
        last_loss = float(m["loss"])
        first = first if first is not None else last_loss
        if step % 10 == 0:
            dt = time.time() - t0
            toks = (step - start + 1) * args.batch * args.seq_len
            print(f"step {step:4d} loss {last_loss:.4f} "
                  f"({toks/max(dt,1e-9):.0f} tok/s)", flush=True)
        if (step + 1) % 50 == 0:
            ckpt.save(args.ckpt_dir, step, state)
    ckpt.save(args.ckpt_dir, args.steps - 1, state)
    print(f"done: loss {first:.3f} -> {last_loss:.3f} "
          f"in {time.time()-t0:.0f}s")
    assert last_loss < first, "training should reduce the loss"


if __name__ == "__main__":
    main()
