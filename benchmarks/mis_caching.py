"""Figure 4 reproduction: effect of the caching optimization on AMPC MIS/MM
KV-store traffic (the multithreading optimization has no TPU analogue —
batched gathers are already parallel; see DESIGN.md §2)."""
from __future__ import annotations

from repro.ampc import AmpcEngine

from .common import DEFAULT_GRAPHS, GRAPHS, fmt_table
from .registry import bench


@bench("mis_caching", takes_graphs=True,
       quick_kwargs={"graph_names": ["rmat12", "er13"]},
       summary="Fig 4: caching (dedup) query savings for MIS/MM")
def run(graph_names=None):
    names = graph_names or list(DEFAULT_GRAPHS)
    eng = AmpcEngine(seed=0)
    rows = []
    for gname in names:
        g = GRAPHS[gname]()
        st = eng.solve(g, "mis").stats
        stm = eng.solve(g, "matching").stats
        rows.append([gname,
                     st["queries_nodedup"], st["queries_dedup"],
                     f"{st['cache_savings_factor']:.2f}x",
                     stm["queries_nodedup"], stm["queries_dedup"],
                     f"{stm['queries_nodedup']/max(stm['queries_dedup'],1):.2f}x"])
    out = fmt_table(["graph", "MIS q (no cache)", "MIS q (cache)", "MIS save",
                     "MM q (no cache)", "MM q (cache)", "MM save"], rows)
    print(out)
    print("\npaper Fig 4: caching reduces KV bytes 1.96-12.2x (MIS), "
          "2.65-8.81x (MM)")
    return {"rows": rows, "markdown": out}


if __name__ == "__main__":
    run()
