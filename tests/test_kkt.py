"""KKT filter (Algorithms 3+5): RMQ, Euler-tour rooting, path-max, F-light."""
import collections

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.graph import generators as gen
from repro.graph.coo import UGraph
from repro.core import kkt_filter as kkt, oracle


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(-1000, 1000), min_size=1, max_size=200),
       st.data())
def test_rmq_sparse_table(xs, data):
    a = jnp.asarray(np.array(xs, np.int32))
    table = kkt.rmq_build(a)
    i = data.draw(st.integers(0, len(xs) - 1))
    j = data.draw(st.integers(i, len(xs) - 1))
    got = int(kkt.rmq_query(table, jnp.asarray([i]), jnp.asarray([j]))[0])
    assert got == min(xs[i:j + 1])


def _brute_pathmax(edges, w, qu, qv):
    adj = collections.defaultdict(list)
    for (a, b), ww in zip(edges, w):
        adj[a].append((b, ww)); adj[b].append((a, ww))
    out = []
    for s, t in zip(qu, qv):
        seen = {int(s): -np.inf}; queue = [int(s)]
        while queue:
            x = queue.pop()
            for y, ww in adj[x]:
                if y not in seen:
                    seen[y] = max(seen[x], ww); queue.append(y)
        out.append(seen.get(int(t), np.inf))
    return np.array(out)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_root_forest_and_path_max(seed):
    n = 80
    g = gen.erdos_renyi(n, 3.0, seed=seed).with_random_weights(seed)
    fmask, _ = oracle.kruskal_msf(g)
    fe, fw = g.edges[fmask], g.weights[fmask]
    K = int(fmask.sum())
    labels = oracle.connected_components(UGraph(n, fe))
    parent, pw, depth = kkt.root_forest(
        jnp.asarray(fe[:, 0]), jnp.asarray(fe[:, 1]), jnp.asarray(fw),
        jnp.ones((K,), bool), n)
    # parent pointers form a valid rooted forest
    p = np.asarray(parent)
    d = np.asarray(depth)
    roots = p == np.arange(n)
    assert (d[roots] == 0).all()
    nonroot = ~roots
    assert (d[nonroot] == d[p[nonroot]] + 1).all()

    rng = np.random.default_rng(seed)
    qu = rng.integers(0, n, 50).astype(np.int32)
    qv = rng.integers(0, n, 50).astype(np.int32)
    levels = int(np.ceil(np.log2(n))) + 1
    maxw, same = kkt.path_max_queries(
        parent, pw, depth, jnp.asarray(labels.astype(np.int32)),
        jnp.asarray(qu), jnp.asarray(qv), levels)
    ref = _brute_pathmax(fe, fw, qu, qv)
    got, sm = np.asarray(maxw), np.asarray(same)
    for i in range(50):
        if qu[i] == qv[i]:
            continue
        assert np.isinf(ref[i]) == (not sm[i])
        if not np.isinf(ref[i]):
            assert abs(ref[i] - got[i]) < 1e-4


def test_f_light_soundness():
    """Proposition 3.8: every true MSF edge must be classified F-light."""
    g = gen.rmat(9, 8.0, seed=1).with_random_weights(2)
    rng = np.random.default_rng(0)
    smask = rng.random(g.m) < 0.3
    h = UGraph(g.n, g.edges[smask], g.weights[smask])
    hmask, _ = oracle.kruskal_msf(h)
    fmask = np.zeros(g.m, bool)
    fmask[np.where(smask)[0][hmask]] = True
    light = kkt.f_light_edges(g, fmask)
    msf_mask, _ = oracle.kruskal_msf(g)
    assert (light[msf_mask]).all(), "an MSF edge was classified F-heavy"


@pytest.mark.parametrize("name,make", [
    ("er", lambda: gen.erdos_renyi(400, 5.0, seed=3).with_random_weights(4)),
    ("rmat", lambda: gen.rmat(10, 8.0, seed=1).with_random_weights(2)),
])
def test_msf_kkt_end_to_end(name, make):
    g = make()
    mo, _ = oracle.kruskal_msf(g)
    mk, stats = kkt.msf_kkt(g, seed=0)
    assert np.array_equal(mo, mk)
    # Lemma 3.9: expected F-light count is O(n/p) = O(n log n)
    assert stats["light_edges"] <= 6 * g.n * np.log(g.n)
