"""TransformerLM covering all five assigned LM architectures.

One config-driven decoder-only LM:
  * dense or MoE FFN (top-1 llama4 w/ shared expert, top-2 mixtral)
  * GQA, optional QKV bias / qk-norm
  * full, sliding-window, or local:global attention patterns
  * layers stacked for ``lax.scan`` (compile-time O(1) in depth)
  * train forward (logits+loss), prefill (build KV cache), decode (one token)

Params layout: {"embed": (V, d), "layers": {<name>: (L, ...)}, "final_norm",
"lm_head" (or tied)}.  Per-layer heterogeneity (local vs global attention) is
expressed as scanned per-layer scalars, keeping a single layer body.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import (AttnParamsSpec, apply_rope, attention_xla,
                     attention_xla_chunked, attn_qkv, init_attn, init_mlp,
                     make_attention_mask, mlp_swiglu, rms_norm)

# sequences >= this use the chunked (flash-style) XLA attention path
CHUNKED_ATTN_THRESHOLD = 2048
from .moe import MoeSpec, init_moe, moe_apply, moe_apply_local


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Activation-sharding constraints (GSPMD hints threaded through the
    model). dp: data-parallel axis name(s); model: tensor-parallel axis."""
    mesh: Any
    dp: Any
    model: str = "model"

    def cs(self, x, *spec):
        from jax.sharding import NamedSharding, PartitionSpec as P
        fixed = []
        for i, ax in enumerate(spec):
            if ax is None:
                fixed.append(None)
                continue
            import numpy as _np
            size = (int(_np.prod([self.mesh.shape[a] for a in ax]))
                    if isinstance(ax, tuple) else self.mesh.shape[ax])
            fixed.append(ax if x.shape[i] % size == 0 else None)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*fixed)))


def _cs(sctx, x, *spec):
    return x if sctx is None else sctx.cs(x, *spec)


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    vocab: int
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    max_seq_len: int = 131072
    sliding_window: int = 0            # 0 = full attention
    local_global_ratio: int = 0        # k => k local layers then 1 global
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0                  # expert hidden size (if != d_ff)
    moe_shared_expert: bool = False
    tie_embeddings: bool = False
    dtype: Any = jnp.bfloat16
    remat: str = "none"                # none | full | dots
    attention_impl: str = "xla"        # xla | pallas
    # perf knobs (EXPERIMENTS.md §Perf)
    attn_chunk_q: int = 512
    attn_chunk_kv: int = 512
    attn_p_bf16: bool = False          # cast softmax P to bf16 before PV dot
    attn_static_skip: bool = False     # static causal chunk skipping (§Perf)
    moe_local_dispatch: bool = False   # per-dp-shard MoE dispatch (§Perf)
    n_microbatches: int = 1            # gradient accumulation inside the step

    @property
    def static_window(self):
        return (self.sliding_window
                if self.sliding_window > 0 and self.local_global_ratio == 0
                else None)

    @property
    def is_moe(self) -> bool:
        return self.moe_experts > 0

    @property
    def attn_spec(self) -> AttnParamsSpec:
        return AttnParamsSpec(self.d_model, self.n_heads, self.n_kv_heads,
                              self.head_dim, self.qkv_bias, self.qk_norm)

    @property
    def moe_spec(self) -> MoeSpec:
        return MoeSpec(self.d_model, self.moe_d_ff or self.d_ff,
                       self.moe_experts, self.moe_top_k,
                       shared_expert=self.moe_shared_expert)

    def layer_windows(self) -> np.ndarray:
        """Per-layer attention window (0 = full)."""
        if self.local_global_ratio > 0:
            r = self.local_global_ratio
            # gemma3 pattern: r local layers, then 1 global
            w = np.full(self.n_layers, self.sliding_window or 1024, np.int32)
            w[r::r + 1] = 0
            return w
        return np.full(self.n_layers, self.sliding_window, np.int32)

    def param_count(self) -> int:
        d, f, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        H, Hkv, hd = self.n_heads, self.n_kv_heads, self.head_dim
        attn = d * (H * hd) + 2 * d * (Hkv * hd) + (H * hd) * d
        if self.is_moe:
            fe = self.moe_d_ff or f
            ffn = self.moe_experts * 3 * d * fe + d * self.moe_experts
            if self.moe_shared_expert:
                ffn += 3 * d * fe
        else:
            ffn = 3 * d * f
        per_layer = attn + ffn + 2 * d
        head = 0 if self.tie_embeddings else V * d
        return V * d + L * per_layer + head + d

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed top-k experts)."""
        if not self.is_moe:
            return self.param_count()
        d, V, L = self.d_model, self.vocab, self.n_layers
        H, Hkv, hd = self.n_heads, self.n_kv_heads, self.head_dim
        fe = self.moe_d_ff or self.d_ff
        attn = d * (H * hd) + 2 * d * (Hkv * hd) + (H * hd) * d
        ffn = self.moe_top_k * 3 * d * fe + d * self.moe_experts
        if self.moe_shared_expert:
            ffn += 3 * d * fe
        per_layer = attn + ffn + 2 * d
        head = 0 if self.tie_embeddings else V * d
        return V * d + L * per_layer + head + d


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def init_params(cfg: TransformerConfig, key, dtype=jnp.float32) -> Dict:
    keys = jax.random.split(key, cfg.n_layers + 3)
    embed = jax.random.normal(keys[0], (cfg.vocab, cfg.d_model), dtype) * 0.02

    def layer_params(k):
        k1, k2 = jax.random.split(k)
        p = {"attn": init_attn(k1, cfg.attn_spec, dtype),
             "ln1": jnp.zeros((cfg.d_model,), dtype),
             "ln2": jnp.zeros((cfg.d_model,), dtype)}
        if cfg.is_moe:
            p["moe"] = init_moe(k2, cfg.moe_spec, dtype)
        else:
            p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, dtype)
        return p

    layers = jax.vmap(layer_params)(jnp.stack(keys[1:cfg.n_layers + 1]))
    params = {"embed": embed, "layers": layers,
              "final_norm": jnp.zeros((cfg.d_model,), dtype)}
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(
            keys[-1], (cfg.d_model, cfg.vocab), dtype) * 0.02
    return params


# --------------------------------------------------------------------------
# forward (training / prefill)
# --------------------------------------------------------------------------
def _layer_body(cfg: TransformerConfig, sctx: Optional[ShardCtx] = None):
    spec = cfg.attn_spec
    dp = sctx.dp if sctx is not None else None
    mdl = sctx.model if sctx is not None else None

    def body(x, layer_p, window, positions, mask_base):
        S = x.shape[1]
        h = rms_norm(x, layer_p["ln1"])
        q, k, v = attn_qkv(layer_p["attn"], h, spec, positions, cfg.rope_theta)
        q = _cs(sctx, q, dp, None, mdl, None)
        k = _cs(sctx, k, dp, None, mdl, None)
        v = _cs(sctx, v, dp, None, mdl, None)
        if cfg.attention_impl == "pallas":
            from ..kernels.flash_attention.ops import flash_attention
            attn_out = flash_attention(q, k, v, causal=True, window=window)
        elif S >= CHUNKED_ATTN_THRESHOLD:
            attn_out = attention_xla_chunked(
                q, k, v, positions, positions, window=window, causal=True,
                chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv,
                p_bf16=cfg.attn_p_bf16,
                static_positions=cfg.attn_static_skip,
                static_window=cfg.static_window)
        else:
            mask = mask_base & jnp.where(
                window > 0,
                (positions[:, :, None] - positions[:, None, :]) < window, True)
            attn_out = attention_xla(q, k, v, mask[:, None, None, :, :])
        attn_flat = _cs(sctx, attn_out.reshape(x.shape[0], x.shape[1], -1),
                        dp, None, mdl)
        x = _cs(sctx, x + attn_flat @ layer_p["attn"]["wo"].astype(x.dtype),
                dp, None, None)
        h2 = rms_norm(x, layer_p["ln2"])
        hidden_cs = (lambda h: _cs(sctx, h, dp, None, mdl)) if sctx else None
        if cfg.is_moe:
            if cfg.moe_local_dispatch and sctx is not None:
                import numpy as _np
                dpn = int(_np.prod([sctx.mesh.shape[a] for a in
                                    (sctx.dp if isinstance(sctx.dp, tuple)
                                     else (sctx.dp,))]))
                ffn_out, aux = moe_apply_local(
                    layer_p["moe"], h2, cfg.moe_spec, dpn,
                    token_cs=lambda t: _cs(sctx, t, dp, None, None),
                    buf_cs=lambda b: _cs(sctx, b, dp, None, None, None),
                    hid_cs=lambda h: _cs(sctx, h, dp, None, None, mdl))
            else:
                ffn_out, aux = moe_apply(
                    layer_p["moe"], h2, cfg.moe_spec,
                    token_cs=(lambda t: _cs(sctx, t, dp, None))
                    if sctx else None)
        else:
            ffn_out, aux = mlp_swiglu(layer_p["mlp"], h2,
                                      hidden_cs=hidden_cs), jnp.float32(0)
        return _cs(sctx, x + ffn_out, dp, None, None), aux

    return body


def forward(cfg: TransformerConfig, params, tokens,
            sctx: Optional[ShardCtx] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens: (B, S) -> (logits (B, S, V), aux_loss)."""
    B, S = tokens.shape
    dp = sctx.dp if sctx is not None else None
    mdl = sctx.model if sctx is not None else None
    x = _cs(sctx, params["embed"].astype(cfg.dtype)[tokens], dp, None, None)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    mask_base = (None if S >= CHUNKED_ATTN_THRESHOLD else
                 make_attention_mask(positions, positions, None, causal=True))
    windows = jnp.asarray(cfg.layer_windows())
    body = _layer_body(cfg, sctx)

    def scan_fn(x, layer):
        layer_p, window = layer
        fn = body
        if cfg.remat == "full":
            fn = jax.checkpoint(body)
        elif cfg.remat == "dots":
            fn = jax.checkpoint(
                body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        x, aux = fn(x, layer_p, window, positions, mask_base)
        return x, aux

    x, auxs = jax.lax.scan(scan_fn, x, (params["layers"], windows))
    x = rms_norm(x, params["final_norm"])
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = _cs(sctx, x @ head.astype(cfg.dtype), dp, None, mdl)
    return logits, auxs.sum()


def loss_fn(cfg: TransformerConfig, params, tokens, labels,
            aux_weight: float = 0.01, sctx: Optional[ShardCtx] = None):
    logits, aux = forward(cfg, params, tokens, sctx=sctx)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold).mean()
    return nll + aux_weight * aux, {"nll": nll, "aux": aux}


# --------------------------------------------------------------------------
# serving: prefill + decode with per-layer KV cache
# --------------------------------------------------------------------------
def prefill(cfg: TransformerConfig, params, tokens,
            sctx: Optional[ShardCtx] = None):
    """Returns (last_logits (B, V), cache dict with k/v (L, B, S, Hkv, hd))."""
    B, S = tokens.shape
    dp = sctx.dp if sctx is not None else None
    mdl = sctx.model if sctx is not None else None
    x = _cs(sctx, params["embed"].astype(cfg.dtype)[tokens], dp, None, None)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    mask_base = (None if S >= CHUNKED_ATTN_THRESHOLD else
                 make_attention_mask(positions, positions, None, causal=True))
    windows = jnp.asarray(cfg.layer_windows())
    spec = cfg.attn_spec

    def scan_fn(x, layer):
        layer_p, window = layer
        h = rms_norm(x, layer_p["ln1"])
        q, k, v = attn_qkv(layer_p["attn"], h, spec, positions, cfg.rope_theta)
        q = _cs(sctx, q, dp, None, mdl, None)
        k = _cs(sctx, k, dp, None, mdl, None)
        v = _cs(sctx, v, dp, None, mdl, None)
        if S >= CHUNKED_ATTN_THRESHOLD:
            attn_out = attention_xla_chunked(
                q, k, v, positions, positions, window=window, causal=True,
                chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv,
                p_bf16=cfg.attn_p_bf16,
                static_positions=cfg.attn_static_skip,
                static_window=cfg.static_window)
        else:
            mask = mask_base & jnp.where(
                window > 0,
                (positions[:, :, None] - positions[:, None, :]) < window, True)
            attn_out = attention_xla(q, k, v, mask[:, None, None, :, :])
        attn_flat = _cs(sctx, attn_out.reshape(B, S, -1), dp, None, mdl)
        x = _cs(sctx, x + attn_flat @ layer_p["attn"]["wo"].astype(x.dtype),
                dp, None, None)
        h2 = rms_norm(x, layer_p["ln2"])
        hidden_cs = (lambda h: _cs(sctx, h, dp, None, mdl)) if sctx else None
        if cfg.is_moe:
            if cfg.moe_local_dispatch and sctx is not None:
                import numpy as _np
                dpn = int(_np.prod([sctx.mesh.shape[a] for a in
                                    (sctx.dp if isinstance(sctx.dp, tuple)
                                     else (sctx.dp,))]))
                ffn_out, _ = moe_apply_local(
                    layer_p["moe"], h2, cfg.moe_spec, dpn,
                    token_cs=lambda t: _cs(sctx, t, dp, None, None),
                    buf_cs=lambda b: _cs(sctx, b, dp, None, None, None),
                    hid_cs=lambda h: _cs(sctx, h, dp, None, None, mdl))
            else:
                ffn_out, _ = moe_apply(
                    layer_p["moe"], h2, cfg.moe_spec,
                    token_cs=(lambda t: _cs(sctx, t, dp, None))
                    if sctx else None)
        else:
            ffn_out = mlp_swiglu(layer_p["mlp"], h2, hidden_cs=hidden_cs)
        return _cs(sctx, x + ffn_out, dp, None, None), (k, v)

    x, (ks, vs) = jax.lax.scan(scan_fn, x, (params["layers"], windows))
    x = rms_norm(x, params["final_norm"])
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = _cs(sctx, x[:, -1] @ head.astype(cfg.dtype), dp, mdl)
    cache = {"k": ks, "v": vs,
             "length": jnp.full((B,), S, jnp.int32)}
    return logits, cache


def decode_step(cfg: TransformerConfig, params, cache, token,
                sctx: Optional[ShardCtx] = None):
    """One decode step. token: (B,) int32; cache k/v: (L, B, S, Hkv, hd).
    The cache is a sliding window ring buffer when cfg bounds the window;
    here S is the allocated cache length and `length` the current fill."""
    L, B, S, Hkv, hd = cache["k"].shape
    x = params["embed"].astype(cfg.dtype)[token][:, None, :]   # (B, 1, d)
    pos = cache["length"][:, None]                              # (B, 1)
    windows = jnp.asarray(cfg.layer_windows())
    spec = cfg.attn_spec
    slot = cache["length"][0] % S   # uniform fill assumed (batch decodes in step)

    def scan_fn(x, layer):
        layer_p, window, k_cache, v_cache = layer
        h = rms_norm(x, layer_p["ln1"])
        q, k_new, v_new = attn_qkv(layer_p["attn"], h, spec, pos,
                                   cfg.rope_theta)
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k_new.astype(k_cache.dtype), (0, slot, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v_new.astype(v_cache.dtype), (0, slot, 0, 0))
        k_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        # ring semantics: absolute position of cache slot i
        cur = cache["length"][0]
        abs_pos = jnp.where(k_pos <= (cur % S), cur - (cur % S) + k_pos,
                            cur - (cur % S) - S + k_pos)
        valid = (abs_pos >= 0) & (abs_pos <= cur)
        mask = valid[:, None, :]
        mask = mask & jnp.where(window > 0,
                                (pos[:, :, None] - abs_pos[:, None, :]) < window,
                                True)
        attn_out = attention_xla(q, k_cache, v_cache,
                                 mask[:, None, None, :, :])
        x = x + attn_out.reshape(B, 1, -1) @ layer_p["attn"]["wo"].astype(x.dtype)
        h2 = rms_norm(x, layer_p["ln2"])
        if cfg.is_moe:
            ffn_out, _ = moe_apply(layer_p["moe"], h2, cfg.moe_spec)
        else:
            ffn_out = mlp_swiglu(layer_p["mlp"], h2)
        return x + ffn_out, (k_cache, v_cache)

    x, (ks, vs) = jax.lax.scan(
        scan_fn, x, (params["layers"], windows, cache["k"], cache["v"]))
    x = rms_norm(x, params["final_norm"])
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x[:, 0] @ head.astype(cfg.dtype)
    new_cache = {"k": ks, "v": vs, "length": cache["length"] + 1}
    return logits, new_cache
