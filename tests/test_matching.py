"""Maximal matching: all four implementations compute the exact LFMM."""
import numpy as np
import pytest

from repro.graph import generators as gen
from repro.core import matching as mm, oracle
from repro.core.rounds import RoundLedger

FAMILIES = [
    ("er", lambda: gen.erdos_renyi(250, 5.0, seed=6)),
    ("rmat", lambda: gen.rmat(9, 10.0, seed=7)),
    ("geo", lambda: gen.random_geometric(120, 1.0, seed=3)[0]),
    ("star", lambda: gen.star(40)),
]


@pytest.mark.parametrize("name,make", FAMILIES)
def test_mm_ampc_is_lfmm(name, make):
    g = make()
    got, st = mm.mm_ampc(g, seed=8)
    want = oracle.greedy_mm(g, st["erank"])
    assert np.array_equal(got, want)
    assert oracle.is_maximal_matching(g, got)


@pytest.mark.parametrize("name,make", FAMILIES)
def test_mm_levels_algorithm4(name, make):
    g = make()
    got, st = mm.mm_ampc_levels(g, seed=8)
    want = oracle.greedy_mm(g, st["erank"])
    assert np.array_equal(got, want)
    # Lemma 4.4: the level count k = ceil(log2 log2 Delta) + 1
    delta = max(int(g.degrees().max()), 2)
    assert st["k"] == int(np.ceil(np.log2(max(np.log2(delta), 1.000001)))) + 1


@pytest.mark.parametrize("name,make", FAMILIES[:2])
def test_mm_vertex_process_theorem2_part2(name, make):
    g = make()
    got, st = mm.mm_ampc_vertex_process(g, epsilon=0.5, seed=8)
    want = oracle.greedy_mm(g, st["erank"])
    assert np.array_equal(got, want)
    # O(1/eps) launches (Lemma 4.7): generous constant
    assert st["launches"] <= 10
    # total space O(m + n^{1+eps})
    assert st["queries"] <= 4 * (g.m + g.n * st["budget"]) + 1000


@pytest.mark.parametrize("name,make", FAMILIES[:2])
def test_mm_mpc_rootset(name, make):
    g = make()
    got, st = mm.mm_mpc_rootset(g, seed=8)
    want = oracle.greedy_mm(g, st["erank"])
    assert np.array_equal(got, want)


def test_shuffle_counts_table3():
    """AMPC MM uses O(1) shuffles; MPC uses 2 per phase (Table 3)."""
    g = gen.rmat(9, 8.0, seed=1)
    la = RoundLedger("ampc_mm")
    mm.mm_ampc(g, seed=0, ledger=la)
    assert la.shuffles == 2
    lm = RoundLedger("mpc_mm")
    _, st = mm.mm_mpc_rootset(g, seed=0, ledger=lm)
    assert lm.shuffles == 2 * st["phases"]
    assert lm.shuffles > la.shuffles


def test_caching_reduces_queries():
    """Fig 4: dedup (caching) reduces KV-store traffic."""
    g = gen.rmat(9, 8.0, seed=2)
    _, st = mm.mm_ampc(g, seed=0)
    assert st["queries_dedup"] < st["queries_nodedup"]
