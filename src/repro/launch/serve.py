"""Serving launcher: batched prefill + decode loop.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.registry import get
from ..models import transformer as tr
from . import steps


def serve(arch: str, smoke: bool, batch: int, prompt_len: int, gen: int,
          seed: int = 0):
    entry = get(arch)
    cfg = entry.smoke_config if smoke else entry.config
    params = tr.init_params(cfg, jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab, (batch, prompt_len)).astype(np.int32)

    prefill = jax.jit(functools.partial(steps.lm_prefill_step, cfg))
    decode = jax.jit(functools.partial(steps.lm_decode_step, cfg),
                     donate_argnums=(1,))

    t0 = time.time()
    logits, cache = prefill(params, jnp.asarray(prompts))
    # grow cache to prompt_len + gen slots
    total = prompt_len + gen
    pad = total - cache["k"].shape[2]
    cache = {"k": jnp.pad(cache["k"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
             "v": jnp.pad(cache["v"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
             "length": cache["length"]}
    t_prefill = time.time() - t0

    out_tokens = []
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t1 = time.time()
    for _ in range(gen):
        out_tokens.append(np.asarray(tok))
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t_decode = time.time() - t1
    gen_mat = np.stack(out_tokens, axis=1)
    return {"generated": gen_mat, "prefill_s": t_prefill,
            "decode_s": t_decode,
            "decode_tok_s": batch * gen / max(t_decode, 1e-9)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-12b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    r = serve(args.arch, args.smoke, args.batch, args.prompt_len, args.gen)
    print(f"prefill {r['prefill_s']:.2f}s decode {r['decode_s']:.2f}s "
          f"({r['decode_tok_s']:.1f} tok/s) sample: {r['generated'][0][:8]}")


if __name__ == "__main__":
    main()
