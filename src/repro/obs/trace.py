"""Span-based tracer: nested wall-time spans with attributes and events.

Design constraints (why this is not just ``logging``):

* **Per-solve attribution** — every ``AmpcEngine.solve`` produces one span
  tree (``AmpcResult.trace``); a ``solve_many`` bucket launch is one span
  whose per-graph children carry each graph's share of the launch, matching
  the per-graph ``RoundLedger`` attribution.
* **~zero cost when disabled** — the hot paths (``RoundLedger.shuffle``,
  ``ShardedDHT.lookup``, the batched adapters) call the tracer
  unconditionally; with the :data:`NOOP_TRACER` every call returns a shared
  singleton and allocates nothing, so a production engine with tracing off
  pays a few attribute loads per solve.
* **Thread-safe collection** — spans nest per thread (a ``threading.local``
  stack); completed root spans are appended to one shared list under a
  lock, so a threaded serving loop can trace into a single tracer.

Timestamps are microseconds since a process-wide epoch (monotonic), which
is exactly what the Chrome-trace exporter needs.

Optional device bridging: ``Tracer(annotate_device=True)`` additionally
wraps every span in a ``jax.profiler.TraceAnnotation`` so the same span
names show up inside device profiles captured with ``jax.profiler.trace``.
"""
from __future__ import annotations

import itertools
import threading
import time
from typing import Any, Dict, List, Optional

_EPOCH = time.perf_counter()


def _now_us() -> int:
    return int((time.perf_counter() - _EPOCH) * 1e6)


class SpanEvent:
    """A timestamped point event attached to a span (e.g. a WARN)."""

    __slots__ = ("name", "ts_us", "level", "attributes")

    def __init__(self, name: str, ts_us: int, level: str = "INFO",
                 attributes: Optional[Dict[str, Any]] = None):
        self.name = name
        self.ts_us = ts_us
        self.level = level
        self.attributes = attributes or {}

    def __repr__(self):
        return f"SpanEvent({self.name!r}, level={self.level!r})"


class Span:
    """One traced region: name, start/duration, attributes, children.

    Used as a context manager (``with tracer.span("phase") as sp:``); also
    produced retroactively by :meth:`Tracer.record_span` for launches whose
    duration was measured externally (the batched ``solve_many`` path).
    """

    __slots__ = ("name", "span_id", "ts_us", "dur_us", "thread_id",
                 "attributes", "events", "children", "_tracer", "_annotation")

    def __init__(self, tracer: "Tracer", name: str,
                 attributes: Optional[Dict[str, Any]] = None):
        self.name = name
        self.span_id = next(tracer._ids)
        self.ts_us = 0
        self.dur_us = 0
        self.thread_id = 0
        self.attributes = dict(attributes) if attributes else {}
        self.events: List[SpanEvent] = []
        self.children: List["Span"] = []
        self._tracer = tracer
        self._annotation = None

    # -- context manager ---------------------------------------------------
    def __enter__(self) -> "Span":
        self._tracer._push(self)
        self.thread_id = threading.get_ident()
        if self._tracer.annotate_device:
            self._annotation = self._tracer._enter_annotation(self.name)
        self.ts_us = _now_us()
        return self

    def __exit__(self, exc_type, exc, tb):
        self.dur_us = _now_us() - self.ts_us
        if self._annotation is not None:
            self._annotation.__exit__(exc_type, exc, tb)
            self._annotation = None
        if exc_type is not None:
            self.attributes["error"] = exc_type.__name__
        self._tracer._pop(self)
        return False

    # -- mutation ----------------------------------------------------------
    def set(self, **attributes) -> "Span":
        """Attach attributes to this span; returns self for chaining."""
        self.attributes.update(attributes)
        return self

    def event(self, name: str, level: str = "INFO", **attributes) -> None:
        self.events.append(SpanEvent(name, _now_us(), level, attributes))

    # -- inspection --------------------------------------------------------
    @property
    def dur_s(self) -> float:
        return self.dur_us / 1e6

    def walk(self):
        """Yield this span and all descendants, depth-first."""
        yield self
        for c in self.children:
            yield from c.walk()

    def find(self, name: str) -> List["Span"]:
        """All descendant spans (incl. self) with the given name."""
        return [s for s in self.walk() if s.name == name]

    def __repr__(self):
        return (f"Span({self.name!r}, dur_us={self.dur_us}, "
                f"children={len(self.children)}, attrs={self.attributes})")


class Tracer:
    """Collects spans; one instance per engine (or per process)."""

    enabled = True

    def __init__(self, annotate_device: bool = False):
        self.annotate_device = bool(annotate_device)
        self._ids = itertools.count(1)
        self._lock = threading.Lock()
        self._roots: List[Span] = []
        self._orphan_events: List[SpanEvent] = []
        self._local = threading.local()

    # -- span lifecycle ----------------------------------------------------
    def span(self, name: str, **attributes) -> Span:
        """Open a new span: ``with tracer.span("solve", problem="mis"):``."""
        return Span(self, name, attributes)

    def record_span(self, name: str, dur_s: float = 0.0,
                    parent: Optional[Span] = None, **attributes) -> Span:
        """Record an already-measured span retroactively.

        Used when a duration was timed externally (e.g. one batched launch
        amortized per graph).  The span ends *now* and starts ``dur_s``
        ago; it attaches under ``parent`` when given, else under the
        current open span of this thread, else as a new root.
        """
        sp = Span(self, name, attributes)
        sp.thread_id = threading.get_ident()
        sp.dur_us = int(dur_s * 1e6)
        sp.ts_us = _now_us() - sp.dur_us
        if parent is not None:
            parent.children.append(sp)
        else:
            stack = getattr(self._local, "stack", None)
            if stack:
                stack[-1].children.append(sp)
            else:
                with self._lock:
                    self._roots.append(sp)
        return sp

    def event(self, name: str, level: str = "INFO", **attributes) -> None:
        """Attach an event to the current span (or the tracer itself)."""
        stack = getattr(self._local, "stack", None)
        if stack:
            stack[-1].event(name, level=level, **attributes)
        else:
            with self._lock:
                self._orphan_events.append(
                    SpanEvent(name, _now_us(), level, attributes))

    def current_span(self) -> Optional[Span]:
        """The innermost open span on this thread, if any.

        Deferred ledger accounting (``RoundLedger.record_queries_deferred``)
        captures this span at record time and back-fills the
        ``dht_queries`` event onto it at harvest, after the span has
        closed — so deferred-mode traces carry the same event structure
        the eager path emits live.
        """
        stack = getattr(self._local, "stack", None)
        return stack[-1] if stack else None

    # -- internals ---------------------------------------------------------
    def _push(self, span: Span) -> None:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        if stack:
            stack[-1].children.append(span)
        stack.append(span)
        _active_stack().append(self)

    def _pop(self, span: Span) -> None:
        stack = self._local.stack
        # tolerate out-of-order exits: pop through to this span
        while stack and stack.pop() is not span:
            pass
        act = _active_stack()
        if act:
            act.pop()
        if not stack:
            with self._lock:
                self._roots.append(span)

    def _enter_annotation(self, name: str):
        try:  # pragma: no cover - depends on jax profiler availability
            from jax.profiler import TraceAnnotation
            ann = TraceAnnotation(name)
            ann.__enter__()
            return ann
        except Exception:
            return None

    # -- inspection --------------------------------------------------------
    def spans(self) -> List[Span]:
        """Snapshot of completed root spans (all threads)."""
        with self._lock:
            return list(self._roots)

    def all_spans(self) -> List[Span]:
        """Flat snapshot of every completed span, depth-first."""
        return [s for root in self.spans() for s in root.walk()]

    def orphan_events(self) -> List[SpanEvent]:
        with self._lock:
            return list(self._orphan_events)

    def clear(self) -> None:
        with self._lock:
            self._roots.clear()
            self._orphan_events.clear()

    def __repr__(self):
        return f"Tracer(roots={len(self.spans())})"


class _NoopSpan:
    """Shared do-nothing span: the disabled-tracing fast path."""

    __slots__ = ()
    name = ""
    dur_us = 0
    ts_us = 0
    attributes: Dict[str, Any] = {}
    events: List[SpanEvent] = []
    children: List[Span] = []

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attributes):
        return self

    def event(self, name, level="INFO", **attributes):
        pass

    def walk(self):
        return iter(())

    def find(self, name):
        return []

    def __repr__(self):
        return "NoopSpan()"


class NoopTracer:
    """Tracing disabled: every method returns a shared singleton.

    ``span()`` / ``record_span()`` hand back the same ``_NoopSpan`` object,
    so instrumented hot paths allocate nothing when tracing is off.
    """

    __slots__ = ()
    enabled = False
    annotate_device = False

    def span(self, name, **attributes):
        return NOOP_SPAN

    def record_span(self, name, dur_s=0.0, parent=None, **attributes):
        return NOOP_SPAN

    def event(self, name, level="INFO", **attributes):
        pass

    def current_span(self):
        return None

    def spans(self):
        return []

    def all_spans(self):
        return []

    def orphan_events(self):
        return []

    def clear(self):
        pass

    def __repr__(self):
        return "NoopTracer()"


NOOP_SPAN = _NoopSpan()
NOOP_TRACER = NoopTracer()

# -- ambient tracer plumbing ----------------------------------------------
# current_tracer(): the tracer owning the innermost open span on this
# thread — lets deep layers with no tracer handle (e.g. runtime.retry)
# attach WARN events to whatever solve/benchmark span is running.
_ACTIVE = threading.local()

# process default: installed by harnesses (benchmarks.run --trace) so
# engines created with trace=None inherit it.
_DEFAULT: Any = NOOP_TRACER
_DEFAULT_LOCK = threading.Lock()


def _active_stack() -> list:
    stack = getattr(_ACTIVE, "stack", None)
    if stack is None:
        stack = _ACTIVE.stack = []
    return stack


def current_tracer():
    """The tracer of the innermost open span on this thread (or no-op)."""
    stack = getattr(_ACTIVE, "stack", None)
    return stack[-1] if stack else NOOP_TRACER


def set_default_tracer(tracer) -> None:
    """Install (or clear, with ``None``) the process-default tracer."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        _DEFAULT = tracer if tracer is not None else NOOP_TRACER


def get_default_tracer():
    return _DEFAULT


def as_tracer(spec) -> Any:
    """Resolve the engine's ``trace=`` argument to a tracer instance.

    ``None`` → the process default (no-op unless a harness installed one);
    ``True`` → a fresh :class:`Tracer`; ``False`` → the no-op tracer;
    a :class:`Tracer`/:class:`NoopTracer` instance passes through.
    """
    if spec is None:
        return get_default_tracer()
    if spec is True:
        return Tracer()
    if spec is False:
        return NOOP_TRACER
    if hasattr(spec, "span") and hasattr(spec, "enabled"):
        return spec
    raise TypeError(f"trace must be None/bool/Tracer, got {type(spec)}")
