"""int8 gradient compression with error feedback for the DP all-reduce.

At 1000+ node scale the data-parallel gradient all-reduce crosses the slow
inter-pod links; 4x compression (int8 vs fp32/bf16) cuts that wire time
directly.  Error feedback keeps SGD/Adam convergent: the quantization
residual is added back into the next step's gradient (Karimireddy et al.,
"EF-SGD").

``compress``/``decompress`` are pure; ``compressed_psum`` shows the
shard_map pattern (quantize -> psum int32 -> dequantize) used when the
framework runs multi-host.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_feedback(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(g: jnp.ndarray, feedback: jnp.ndarray
             ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (q int8, scale f32 scalar, new_feedback)."""
    corrected = g.astype(jnp.float32) + feedback
    scale = jnp.maximum(jnp.abs(corrected).max(), 1e-12) / 127.0
    q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
    new_feedback = corrected - q.astype(jnp.float32) * scale
    return q, scale, new_feedback


def decompress(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_tree(grads, feedback):
    """Tree-wise compression. Returns (q_tree, scale_tree, new_feedback)."""
    out = jax.tree.map(compress, grads, feedback)
    q = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    s = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    fb = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return q, s, fb


def decompress_tree(q, s):
    return jax.tree.map(decompress, q, s)


def compressed_psum(grads, feedback, axis_name: str):
    """Inside shard_map: per-device quantize, int32 psum, mean-dequantize.
    Scales are psum-averaged (per-tensor max-scale is shared via a second
    tiny all-reduce)."""
    q, s, fb = compress_tree(grads, feedback)
    # share a common scale (max across devices) so the int sum is coherent
    s_max = jax.tree.map(lambda x: jax.lax.pmax(x, axis_name), s)
    q2 = jax.tree.map(
        lambda g, fbk, sm: jnp.clip(
            jnp.round((g.astype(jnp.float32) + fbk) / sm), -127, 127
        ).astype(jnp.int8), grads, feedback, s_max)
    fb2 = jax.tree.map(
        lambda g, fbk, qq, sm: g.astype(jnp.float32) + fbk
        - qq.astype(jnp.float32) * sm, grads, feedback, q2, s_max)
    summed = jax.tree.map(
        lambda qq: jax.lax.psum(qq.astype(jnp.int32), axis_name), q2)
    n = jax.lax.psum(1, axis_name)
    avg = jax.tree.map(lambda sq, sm: sq.astype(jnp.float32) * sm / n,
                       summed, s_max)
    return avg, fb2
