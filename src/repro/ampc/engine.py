"""``AmpcEngine`` — one entry point for every AMPC algorithm in the repo.

    from repro.ampc import AmpcEngine
    eng = AmpcEngine(dht_backend="local", epsilon=0.5, seed=0)
    res = eng.solve(graph, "mis")
    res.output                  # bool (n,) membership mask
    res.ledger["shuffles"]      # Table-3 materialized round count
    res.stats                   # algorithm-specific stats, stable key names

The engine owns the three things every pre-engine call site threaded by
hand: the ``RoundLedger`` (created per solve, summarized on the result),
the DHT backend (local gather vs routed all_to_all — pluggable, identical
accounting), and the seed/epsilon defaults.  Problems are resolved through
:mod:`repro.ampc.registry`, so a new algorithm becomes engine-callable by
decorating its adapter with ``@problem(...)``.

For serving many graphs per call, :meth:`AmpcEngine.solve_many` pads the
fleet into power-of-two shape buckets and runs each bucket as one vmapped
launch, memoizing the traced solver per ``(problem, backend, bucket)`` in
an engine-level :class:`~repro.ampc.cache.SolverCache`
(see :meth:`AmpcEngine.cache_info`).

Observability (``repro.obs``): ``AmpcEngine(trace=True)`` records every
solve as a span tree (``AmpcResult.trace``; export with
``repro.obs.export.write_chrome_trace``), and the engine reports counters
and latency histograms into a metrics registry —
:meth:`AmpcEngine.metrics_report` renders it.  Both hooks default to
disabled/no-op paths that cost essentially nothing per solve.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from ..core.rounds import RoundLedger
from ..graph import batching
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from . import registry
from .async_engine import AsyncEngineMixin
from .backends import DhtBackend, resolve_backend
from .cache import CacheInfo, SolverCache
from .session import GraphSession


def _field_eq(a, b) -> bool:
    """Equality that tolerates numpy arrays nested in outputs/stats."""
    if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
        return np.array_equal(a, b)
    if isinstance(a, dict) and isinstance(b, dict):
        return a.keys() == b.keys() and \
            all(_field_eq(a[k], b[k]) for k in a)
    if isinstance(a, (list, tuple)) and isinstance(b, (list, tuple)):
        return len(a) == len(b) and \
            all(_field_eq(x, y) for x, y in zip(a, b))
    return a == b


@dataclasses.dataclass(eq=False)
class AmpcResult:
    """Uniform result of ``AmpcEngine.solve`` / ``AmpcEngine.solve_many``.

    ``output`` follows the problem's declared kind: ``vertex_mask`` (bool
    (n,)), ``edge_mask`` (bool (m,)), ``labels`` (int (n,)), or ``count``
    (int).  ``ledger`` is the ``RoundLedger.summary()`` dict —
    ``ledger["shuffles"]`` is the paper's Table-3 round count.

    ``raw_ledger`` keeps the live ledger for phase-time inspection; it is
    excluded from equality (``compare=False``), and ``__eq__`` compares the
    remaining fields with array-aware semantics, so results holding numpy
    outputs compare cleanly instead of raising.

    >>> from repro.ampc import AmpcEngine
    >>> from repro.graph import generators as gen
    >>> res = AmpcEngine(seed=0).solve(gen.erdos_renyi(64, 3.0, seed=1), "mis")
    >>> res.problem, res.model, res.backend
    ('mis', 'ampc', 'local')
    >>> res.shuffles == res.ledger["shuffles"] == 2
    True
    >>> bool(res.output.any())
    True
    """

    problem: str
    model: str                      # "ampc" | "mpc"
    backend: str                    # DHT backend name used for the solve
    output: Any
    stats: Dict[str, Any]
    ledger: Dict[str, Any]
    wall_time_s: float
    raw_ledger: Optional[RoundLedger] = dataclasses.field(
        repr=False, compare=False, default=None)
    # obs.trace.Span for this solve when the engine traces (compare=False:
    # outputs stay bit-identical with tracing on vs off, and == agrees)
    trace: Optional[Any] = dataclasses.field(
        repr=False, compare=False, default=None)

    @property
    def shuffles(self) -> int:
        return self.ledger["shuffles"]

    def __eq__(self, other):
        if not isinstance(other, AmpcResult):
            return NotImplemented
        return all(_field_eq(getattr(self, f.name), getattr(other, f.name))
                   for f in dataclasses.fields(self) if f.compare)

    def __repr__(self):
        return (f"AmpcResult(problem={self.problem!r}, model={self.model!r}, "
                f"backend={self.backend!r}, shuffles={self.shuffles}, "
                f"dht_queries={self.ledger['dht_queries']}, "
                f"wall_time_s={self.wall_time_s:.3f})")


@dataclasses.dataclass
class SolveContext:
    """Cross-cutting state handed to every registered solver."""

    ledger: RoundLedger
    dht: DhtBackend
    seed: int
    epsilon: float
    mesh: Any = None


@dataclasses.dataclass
class BatchSolveContext:
    """Cross-cutting state handed to a batch adapter for one bucket launch.

    ``ledgers`` holds one ``RoundLedger`` per graph in the batch (batch
    order): the single physical launch is attributed per graph — each ledger
    records the bucket's shuffle structure with that graph's own bytes and
    its own share of the DHT query counts (split by mask).
    """

    ledgers: List[RoundLedger]
    dht: DhtBackend
    seed: int
    epsilon: float
    cache: SolverCache
    problem: str = ""
    backend_name: str = ""
    mesh: Any = None

    def solver_key(self, batch, *extra):
        """Cache key for this bucket's compiled solver.  ``extra`` captures
        options that change the traced program (e.g. a static walk budget)."""
        return (self.problem, self.backend_name,
                batch.n_bucket, batch.m_bucket, *extra)


class AmpcEngine(AsyncEngineMixin):
    """Session object for AMPC graph solves.

    Parameters
    ----------
    mesh:         optional jax mesh handed to the routed backend (a 1-D mesh
                  over all devices is built when omitted).
    dht_backend:  ``"local"`` | ``"routed"`` | a ``DhtBackend`` instance.
    epsilon:      the paper's space exponent (per-machine space n^ε).
    seed:         default randomness for rank permutations / sampling.
    trace:        ``True`` → record every solve as a span tree on a fresh
                  tracer (``engine.tracer``); ``False`` → tracing off; a
                  ``repro.obs.Tracer`` instance to share one tracer across
                  engines; ``None`` (default) → the process-default tracer
                  (no-op unless a harness installed one, e.g.
                  ``benchmarks.run --trace``).
    metrics:      a ``repro.obs.MetricsRegistry``, ``False`` to disable, or
                  ``None`` (default) for the process-wide registry.
    record_events:force the ``RoundLedger.events`` raw-string log on/off for
                  every solve; ``None`` (default) keeps it on for ``solve``
                  and **off inside ``solve_many`` bucket loops**, so
                  long-lived serving sessions don't accumulate strings.
    max_workers:  size of the async worker pool behind ``engine.submit``
                  (lazy: no threads exist until the first submit).
    queue_depth:  bound on the submit queue before ``submit`` blocks for
                  backpressure; default ``2 * max_workers``.
    serialize_launches: hold one engine-wide lock around every device
                  launch, so concurrent async solves overlap host-side
                  phases but never race on the device (the AMPC accounting
                  model runs one materialized round at a time).  Disable
                  only for experiments on multi-controller setups.
    deferred_accounting: ``True`` (default) → per-solve ledgers queue DHT
                  counters on the device and the solve performs exactly one
                  ``jax.device_get`` harvest at result materialization
                  (once per bucket under ``solve_many``); counter values
                  and traces are bit-identical to the eager path.
                  ``False`` → the pre-deferral behavior: every lookup
                  syncs its counts to the host immediately.

    >>> from repro.ampc import AmpcEngine
    >>> from repro.graph import generators as gen
    >>> eng = AmpcEngine(dht_backend="local", epsilon=0.5, seed=0)
    >>> fleet = [gen.erdos_renyi(48, 3.0, seed=s) for s in range(3)]
    >>> results = eng.solve_many(fleet, "mis")
    >>> [r.problem for r in results]
    ['mis', 'mis', 'mis']
    >>> sequential = eng.solve(fleet[0], "mis")
    >>> bool((results[0].output == sequential.output).all())
    True
    >>> eng.cache_info().misses >= 1
    True

    Tracing is one flag away; the per-solve span lands on the result:

    >>> eng = AmpcEngine(seed=0, trace=True)
    >>> res = eng.solve(gen.erdos_renyi(32, 2.0, seed=2), "mis")
    >>> res.trace.name, res.trace.attributes["problem"]
    ('solve', 'mis')
    >>> [c.name for c in res.trace.children]
    ['shuffle:DirectEdges+WriteKV', 'shuffle:IsInMIS']

    Async serving (``submit`` -> future) and snapshot reuse on one graph
    (``session``; see ``repro.ampc.session``):

    >>> with AmpcEngine(seed=0) as eng:
    ...     g = gen.erdos_renyi(48, 3.0, seed=3)
    ...     fut = eng.submit(g, "mis")
    ...     async_res = fut.result(timeout=60)
    ...     sess = eng.session(g)
    ...     cold = sess.solve("mis")
    ...     warm = sess.solve("matching")
    >>> bool((async_res.output == cold.output).all())
    True
    >>> cold.stats["snapshot"]["hit"], warm.stats["snapshot"]["hit"]
    (False, True)
    >>> warm.ledger["shuffles"]   # the WriteKV shuffle was skipped
    1
    """

    def __init__(self, mesh=None, dht_backend="local", epsilon: float = 0.5,
                 seed: int = 0, *, trace=None, metrics=None,
                 record_events: Optional[bool] = None, max_workers: int = 4,
                 queue_depth: Optional[int] = None,
                 serialize_launches: bool = True,
                 deferred_accounting: bool = True):
        self.mesh = mesh
        self.dht = resolve_backend(dht_backend, mesh=mesh)
        self.epsilon = float(epsilon)
        self.seed = int(seed)
        self.deferred_accounting = bool(deferred_accounting)
        self.tracer = obs_trace.as_tracer(trace)
        self.metrics = obs_metrics.as_registry(metrics)
        self.record_events = record_events
        self._solver_cache = SolverCache(metrics=self.metrics)
        # snapshot store for GraphSessions; separate from the solver cache
        # so solver hit/miss accounting stays comparable across versions
        self._snapshot_cache = SolverCache()
        self._launch_lock = (threading.RLock() if serialize_launches
                             else contextlib.nullcontext())
        self._init_async(max_workers, queue_depth)

    # ------------------------------------------------------------------
    def _ledger(self, spec, record_events: bool) -> RoundLedger:
        tracer = self.tracer
        return RoundLedger(
            f"{spec.model}_{spec.name}",
            tracer=tracer if tracer.enabled else None,
            metrics=self.metrics, record_events=record_events,
            deferred=self.deferred_accounting)

    def _observe_solve(self, spec, wall: float, mode: str) -> None:
        m = self.metrics
        if m is None:
            return
        m.histogram("solve_latency_s",
                    labelnames=("problem", "backend")).observe(
                        wall, problem=spec.name, backend=self.dht.name)
        m.counter("solves_total",
                  labelnames=("problem", "backend", "mode")).inc(
                      1, problem=spec.name, backend=self.dht.name, mode=mode)

    # ------------------------------------------------------------------
    def _validate(self, spec, graph) -> None:
        if spec.needs_weights and getattr(graph, "weights", None) is None:
            raise ValueError(
                f"problem {spec.name!r} needs edge weights; call "
                "g.with_random_weights()/g.with_degree_weights() first")
        if spec.needs_cycles and not (graph.degrees() == 2).all():
            raise ValueError(
                f"problem {spec.name!r} needs a disjoint union of cycles "
                "(every vertex must have degree 2)")

    # ------------------------------------------------------------------
    def solve(self, graph, problem: str, *, seed: Optional[int] = None,
              epsilon: Optional[float] = None,
              record_events: Optional[bool] = None, **opts) -> AmpcResult:
        """Run ``problem`` on ``graph`` and return an ``AmpcResult``.

        ``**opts`` are forwarded to the registered solver (e.g.
        ``skip_ternarize_if_dense=False`` for msf, ``p=1/64`` for
        one-vs-two).  ``seed``/``epsilon``/``record_events`` override the
        engine defaults for this solve only.
        """
        spec = registry.get(problem)
        self._validate(spec, graph)
        if record_events is None:
            record_events = self.record_events
        ledger = self._ledger(spec, True if record_events is None
                              else record_events)
        ctx = SolveContext(
            ledger=ledger, dht=self.dht,
            seed=self.seed if seed is None else int(seed),
            epsilon=self.epsilon if epsilon is None else float(epsilon),
            mesh=self.mesh)
        tracer = self.tracer
        span = None
        t0 = time.perf_counter()
        # the launch lock serializes device work across async workers; the
        # wait for it is part of the solve span (device-contention time)
        if tracer.enabled:
            with tracer.span("solve", problem=spec.name, model=spec.model,
                             backend=self.dht.name, n=int(graph.n),
                             m=int(graph.m)) as span:
                with self._launch_lock:
                    output, stats = spec.fn(ctx, graph, **opts)
        else:
            with self._launch_lock:
                output, stats = spec.fn(ctx, graph, **opts)
        wall = time.perf_counter() - t0
        self._observe_solve(spec, wall, "solve")
        return AmpcResult(problem=spec.name, model=spec.model,
                          backend=self.dht.name, output=output, stats=stats,
                          ledger=ledger.summary(), wall_time_s=wall,
                          raw_ledger=ledger, trace=span)

    # ------------------------------------------------------------------
    def solve_many(self, graphs: Sequence[Any], problem: str, *,
                   seed: Optional[int] = None,
                   epsilon: Optional[float] = None,
                   record_events: Optional[bool] = None,
                   **opts) -> List[AmpcResult]:
        """Solve ``problem`` on a fleet of graphs, one result per graph.

        Graphs are padded into power-of-two ``(n_bucket, m_bucket)`` shape
        buckets (:mod:`repro.graph.batching`); each bucket runs as a single
        vmapped/jitted launch whose traced solver is memoized in the
        engine's :class:`SolverCache`, so repeated traffic on same-sized
        graphs skips tracing entirely.  Outputs are unpadded back to
        per-graph ``AmpcResult`` objects identical to sequential ``solve``
        outputs; ``wall_time_s`` is the bucket launch amortized over its
        occupants.

        Bucket-loop ledgers default to ``record_events=False`` (the
        structured trace supersedes the raw strings; pass
        ``record_events=True`` to keep them).  With tracing enabled each
        bucket launch is one ``bucket`` span whose per-graph ``graph[i]``
        children carry that graph's ledger attribution (phase shares from
        ``RoundLedger.record_shuffle``); ``result.trace`` points at the
        graph's own span.

        Problems without a registered batch adapter (see
        ``src/repro/ampc/README.md`` for the list) fall back to sequential
        ``solve`` calls — same results, no batching speedup.
        """
        graphs = list(graphs)
        spec = registry.get(problem)
        for g in graphs:
            self._validate(spec, g)
        if record_events is None:
            record_events = self.record_events
        rec = False if record_events is None else record_events
        if spec.batch_fn is None:
            return [self.solve(g, problem, seed=seed, epsilon=epsilon,
                               record_events=rec, **opts)
                    for g in graphs]
        tracer = self.tracer
        results: List[Optional[AmpcResult]] = [None] * len(graphs)
        root = tracer.span("solve_many", problem=spec.name,
                           backend=self.dht.name, n_graphs=len(graphs)) \
            if tracer.enabled else None
        if root is not None:
            root.__enter__()
        try:
            for batch in batching.bucketize(graphs).values():
                self._solve_bucket(spec, batch, results, rec,
                                   seed=seed, epsilon=epsilon, **opts)
        finally:
            if root is not None:
                root.__exit__(None, None, None)
        return results

    def _solve_bucket(self, spec, batch, results, rec, *, seed, epsilon,
                      **opts) -> None:
        """One bucket launch of ``solve_many``: run, attribute, trace."""
        tracer = self.tracer
        # tracer=None on bucket ledgers: one physical launch must not emit
        # B copies of every shuffle span — the per-graph share is attached
        # retroactively below, from each ledger's phase_times.
        ledgers = [RoundLedger(f"{spec.model}_{spec.name}",
                               metrics=self.metrics, record_events=rec,
                               deferred=self.deferred_accounting)
                   for _ in range(len(batch))]
        bctx = BatchSolveContext(
            ledgers=ledgers, dht=self.dht,
            seed=self.seed if seed is None else int(seed),
            epsilon=self.epsilon if epsilon is None else float(epsilon),
            cache=self._solver_cache, problem=spec.name,
            backend_name=self.dht.name, mesh=self.mesh)
        bspan = tracer.span(
            "bucket", problem=spec.name, n_bucket=batch.n_bucket,
            m_bucket=batch.m_bucket, batch_size=len(batch)) \
            if tracer.enabled else None
        t0 = time.perf_counter()
        if bspan is not None:
            with bspan:
                with self._launch_lock:
                    outs = spec.batch_fn(bctx, batch, **opts)
        else:
            with self._launch_lock:
                outs = spec.batch_fn(bctx, batch, **opts)
        wall = time.perf_counter() - t0
        assert len(outs) == len(batch), \
            f"batch adapter for {spec.name!r} returned {len(outs)} " \
            f"results for {len(batch)} graphs"
        per_graph_wall = wall / max(len(batch), 1)
        for slot, (idx, (output, stats)) in enumerate(
                zip(batch.indices, outs)):
            stats.setdefault("batch", {
                "bucket": batch.key, "batch_size": len(batch),
                "slot": slot})
            ledger = ledgers[slot]
            gspan = None
            if bspan is not None:
                gspan = tracer.record_span(
                    f"graph[{idx}]", dur_s=per_graph_wall, parent=bspan,
                    problem=spec.name, bucket=batch.key, slot=slot)
                for phase, secs in ledger.phase_times.items():
                    tracer.record_span(f"shuffle:{phase}", dur_s=secs,
                                       parent=gspan,
                                       algorithm=ledger.algorithm)
            self._observe_solve(spec, per_graph_wall, "solve_many")
            results[idx] = AmpcResult(
                problem=spec.name, model=spec.model,
                backend=self.dht.name, output=output, stats=stats,
                ledger=ledger.summary(),
                wall_time_s=per_graph_wall, raw_ledger=ledger,
                trace=gspan)

    # ------------------------------------------------------------------
    def session(self, graph) -> GraphSession:
        """A :class:`~repro.ampc.session.GraphSession` on ``graph``: solves
        through it share one DHT graph-KV snapshot (built on first use,
        reported in ``AmpcResult.stats["snapshot"]``)."""
        return GraphSession(self, graph)

    def cache_info(self, kind: str = "solver") -> CacheInfo:
        """Hit/miss/size counters of an engine cache.

        ``kind="solver"`` (default): the compiled-solver cache — one miss
        per solver actually traced; one hit per graph served by an
        already-traced solver (so a cold bucket of ``B`` graphs counts
        ``1`` miss and ``B - 1`` hits).  ``kind="snapshot"``: the
        GraphSession snapshot store — one miss per snapshot built, one hit
        per solve that reused it.
        """
        if kind == "solver":
            return self._solver_cache.info()
        if kind == "snapshot":
            return self._snapshot_cache.info()
        raise ValueError(
            f"kind must be 'solver' or 'snapshot', got {kind!r}")

    def clear_cache(self) -> None:
        """Drop every memoized solver and graph snapshot, and reset both
        caches' hit/miss counters."""
        self._solver_cache.clear()
        self._snapshot_cache.clear()

    def metrics_report(self) -> str:
        """Plain-text dump of this engine's metrics registry.

        One line per labeled series (``name{labels} value``); histograms
        show count/sum/percentiles.  Empty string when ``metrics=False``.
        """
        from ..obs.export import metrics_report
        return metrics_report(self.metrics)

    # ------------------------------------------------------------------
    def problems(self, model: Optional[str] = None):
        """Names of every solvable problem (optionally one model only)."""
        return registry.names(model)

    def baseline_for(self, problem: str) -> Optional[str]:
        """Name of the MPC baseline registered for an AMPC problem."""
        for spec in registry.specs("mpc"):
            if spec.baseline_of == registry.get(problem).name:
                return spec.name
        return None

    def __repr__(self):
        return (f"AmpcEngine(dht_backend={self.dht.name!r}, "
                f"epsilon={self.epsilon}, seed={self.seed})")
