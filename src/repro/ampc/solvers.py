"""Normalized AMPC / MPC solver drivers — the engine's algorithm layer.

Every driver that used to live at module level in ``core.mis`` /
``core.matching`` / ``core.msf`` / ``core.connectivity`` /
``core.weighted_matching`` / ``core.one_vs_two`` now lives here with a
*normalized* surface:

  * the jitted numerical primitives (fixpoints, truncated Prim, Borůvka,
    pointer jumping, walks) stay in their ``core`` modules;
  * each driver accepts the same cross-cutting keywords (``seed``,
    ``ledger``, and — for AMPC solvers with array outputs — an optional
    ``dht`` backend for the final CollectOutputs snapshot read);
  * each driver is registered with :mod:`repro.ampc.registry` so
    ``AmpcEngine.solve(graph, "<problem>")`` reaches it uniformly;
  * batch-safe problems additionally register a ``@batched_impl`` adapter
    (bottom of this module) that runs one vmapped launch per
    ``solve_many`` shape bucket with outputs identical to the sequential
    driver.

The old ``core`` module functions remain as thin deprecated shims that
delegate here, so pre-engine call sites keep working unchanged.

The ``dht`` parameter realizes the paper's last step of every AMPC round:
machines read their outputs back from the immutable DHT snapshot.  With the
``local`` backend that read is a device gather; with the ``routed`` backend
it is a real dedup + all_to_all exchange.  Both report through the same
ledger path, so ``AmpcResult.ledger`` is backend-independent except for the
collect-read traffic itself.
"""
from __future__ import annotations

import functools
import time
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.coo import UGraph
from ..core.rounds import RoundLedger, harvest_many, nbytes_of
from ..core.ternarize import ternarize, ternarize_batch
from ..core.mis import _mis_fixpoint, _mis_fixpoint_masked, IN, OUT, UNKNOWN
from ..core.matching import _mm_fixpoint, _mm_wave, BIGF
from ..core.msf import (truncated_prim, truncated_prim_capped, pointer_jump,
                        contract_edges, boruvka_core, boruvka_inround,
                        _mpc_boruvka_phase)
from ..core.connectivity import (_canonicalize, _cc_fixpoint_masked,
                                 _h2m_phase)
from ..core.one_vs_two import cycle_adjacency, _walk_and_count, \
    _walk_and_count_batch, _local_contraction_phase
from . import registry
from .registry import batched_impl, problem


def _collect_dev(dht, ledger, values, keys=None, dedup: bool = False):
    """CollectOutputs: read an output snapshot back through the DHT backend.

    ``dht=None`` (legacy call sites) returns the device array unchanged.
    With a backend, the read is a genuine lookup (local gather or routed
    all_to_all) whose queries/bytes land in the ledger — as deferred
    device records under a ``deferred`` ledger.  The result stays on the
    device: the caller materializes it through the solve's single
    :meth:`RoundLedger.harvest`.
    """
    if dht is None:
        return values
    if keys is None:
        keys = jnp.arange(values.shape[0], dtype=jnp.int32)
    return dht.lookup(values, keys, ledger=ledger, dedup=dedup)


# ==========================================================================
# MIS (paper Proposition 4.2 / Section 5.3)
# ==========================================================================
def mis_ampc(g: UGraph, seed: int = 0,
             ledger: Optional[RoundLedger] = None,
             caching: bool = True, dht=None,
             snapshot=None) -> Tuple[np.ndarray, dict]:
    """Returns (in_mis bool(n,), stats).

    ``snapshot`` (a :class:`~repro.ampc.session.GraphSnapshot`) replaces
    shuffle 1 with a read of the session's cached graph-KV image: cold it
    records one ``WriteGraphKV`` shuffle, warm it records none — the rank
    permutation is still drawn per solve, so outputs stay bit-identical to
    the snapshot-free path.
    """
    ledger = ledger if ledger is not None else RoundLedger("ampc_mis")
    n = g.n
    rng = np.random.default_rng(seed)
    rank = rng.permutation(n).astype(np.float32)

    snap_stat = None
    if snapshot is not None:
        entries, snap_hit = snapshot.materialize(ledger)
        senders = entries["sym_senders"]
        receivers = entries["sym_receivers"]
        jrank = jnp.asarray(rank)
        snap_stat = snapshot.stat(snap_hit)
    else:
        # shuffle 1: build the rank-directed graph, write to the DHT
        # (Fig 1 step 1-2)
        with ledger.shuffle("DirectEdges+WriteKV", nbytes_of(g.edges) * 2):
            s, r, _, _ = g.symmetric()
            senders = jnp.asarray(s); receivers = jnp.asarray(r)
            jrank = jnp.asarray(rank)

    # shuffle 2: IsInMIS search — adaptive queries against the snapshot
    with ledger.shuffle("IsInMIS", n * 4):
        status_dev, iters, q0, q1 = _mis_fixpoint(senders, receivers, jrank, n)
        out_dev = _collect_dev(dht, ledger, status_dev)
        # the solve's one transfer: outputs + every deferred counter record
        status, it, qn, qd = ledger.harvest((out_dev, iters, q0, q1))
        status = np.asarray(status)
        it, qn, qd = int(it), int(qn), int(qd)
    queries = qd if caching else qn
    row_bytes = 8  # nodeid + status
    ledger.record_queries(queries, queries * row_bytes, waves=it,
                          deduped_away=(qn - qd) if caching else 0)
    assert not (status == UNKNOWN).any()
    stats = {"fixpoint_iters": it, "queries_nodedup": qn,
             "queries_dedup": qd,
             "cache_savings_factor": qn / max(qd, 1)}
    if snap_stat is not None:
        stats["snapshot"] = snap_stat
    return status == IN, stats


def mis_mpc_rootset(g: UGraph, seed: int = 0,
                    ledger: Optional[RoundLedger] = None,
                    max_phases: int = 500) -> Tuple[np.ndarray, dict]:
    ledger = ledger if ledger is not None else RoundLedger("mpc_mis")
    n = g.n
    rng = np.random.default_rng(seed)
    rank = jnp.asarray(rng.permutation(n).astype(np.float32))
    s, r, _, _ = g.symmetric()
    senders = jnp.asarray(s); receivers = jnp.asarray(r)

    @jax.jit
    def phase(status):
        s_unk = status[senders] == UNKNOWN
        lower = rank[receivers] < rank[senders]
        blocked = s_unk & lower & (status[receivers] != OUT)
        has_block = jax.ops.segment_max(blocked.astype(jnp.int32), senders,
                                        num_segments=n)
        nbr_in = s_unk & (status[receivers] == IN)
        has_in = jax.ops.segment_max(nbr_in.astype(jnp.int32), senders,
                                     num_segments=n)
        unk = status == UNKNOWN
        status = jnp.where(unk & (has_in > 0), OUT, status)
        status = jnp.where(unk & (has_in <= 0) & (has_block <= 0), IN, status)
        return status, (status == UNKNOWN).sum()

    status = jnp.zeros((n,), jnp.int32)
    phases = 0
    nb = nbytes_of(g.edges) * 2
    remaining = n
    while remaining > 0 and phases < max_phases:
        # paper Fig 2: 2 shuffles per phase (mark-to-remove join, removal join)
        with ledger.shuffle(f"rootset_mark_{phases}", nb):
            status, rem = phase(status)
        with ledger.shuffle(f"rootset_remove_{phases}", nb):
            remaining = int(jax.device_get(rem))
        phases += 1
    status = np.asarray(jax.device_get(status))
    return status == IN, {"phases": phases}


# ==========================================================================
# Maximal matching (paper Section 4, Theorem 2)
# ==========================================================================
def mm_ampc(g: UGraph, seed: int = 0,
            ledger: Optional[RoundLedger] = None,
            caching: bool = True, erank: Optional[np.ndarray] = None,
            dht=None, snapshot=None) -> Tuple[np.ndarray, dict]:
    """Greedy maximal matching over the rank permutation ``erank``.

    ``erank`` is the rank-injection point (Corollary 4.1): when omitted it
    is a fresh random permutation drawn from ``seed``; weighted matching
    passes decreasing-weight ranks instead.  ``snapshot`` reuses a
    session's cached graph-KV image in place of the ``SortEdges+WriteKV``
    shuffle (see :func:`mis_ampc`).  Returns (in_mm bool(m,), stats).
    """
    ledger = ledger if ledger is not None else RoundLedger("ampc_mm")
    n, m = g.n, g.m
    if erank is None:
        rng = np.random.default_rng(seed)
        erank = rng.permutation(m).astype(np.float32)
    else:
        erank = np.asarray(erank, np.float32)
        assert erank.shape == (m,), "erank must be one rank per edge"

    snap_stat = None
    if snapshot is not None:
        entries, snap_hit = snapshot.materialize(ledger)
        u = entries["edge_u"]; v = entries["edge_v"]
        jrank = jnp.asarray(erank)
        snap_stat = snapshot.stat(snap_hit)
    else:
        with ledger.shuffle("SortEdges+WriteKV", nbytes_of(g.edges) * 2):
            u = jnp.asarray(g.edges[:, 0]); v = jnp.asarray(g.edges[:, 1])
            jrank = jnp.asarray(erank)

    with ledger.shuffle("IsInMM", m):
        estatus_dev, iters, q0, q1 = _mm_fixpoint(
            u, v, jrank, n, jnp.zeros((m,), jnp.int32))
        out_dev = _collect_dev(dht, ledger, estatus_dev)
        estatus, it, qn, qd = ledger.harvest((out_dev, iters, q0, q1))
        estatus = np.asarray(estatus)
        it, qn, qd = int(it), int(qn), int(qd)
    queries = qd if caching else qn
    ledger.record_queries(queries, queries * 12, waves=it,
                          deduped_away=(qn - qd) if caching else 0)
    stats = {"fixpoint_iters": it, "queries_nodedup": qn,
             "queries_dedup": qd, "erank": erank}
    if snap_stat is not None:
        stats["snapshot"] = snap_stat
    return estatus == IN, stats


def mm_ampc_levels(g: UGraph, seed: int = 0,
                   ledger: Optional[RoundLedger] = None) -> Tuple[np.ndarray, dict]:
    """Algorithm 4: O(log log Δ) geometric sampling levels."""
    ledger = ledger if ledger is not None else RoundLedger("ampc_mm_levels")
    n, m = g.n, g.m
    rng = np.random.default_rng(seed)
    erank01 = rng.permutation(m).astype(np.float64) / max(m, 1)  # π(e) in [0,1)
    delta = int(g.degrees().max()) if m else 1
    k = int(np.ceil(np.log2(max(np.log2(max(delta, 2)), 1.000001)))) + 1
    u = jnp.asarray(g.edges[:, 0]); v = jnp.asarray(g.edges[:, 1])
    jrank = jnp.asarray(erank01.astype(np.float32))
    estatus = jnp.zeros((m,), jnp.int32)
    level_stats = []
    ten_log_n = 10 * np.log(max(n, 2))
    for i in range(1, k + 1):
        # current degree of the residual graph
        unk = estatus == UNKNOWN
        deg = np.zeros(n, np.int64)
        eun = np.asarray(jax.device_get(unk))
        np.add.at(deg, g.edges[eun, 0], 1)
        np.add.at(deg, g.edges[eun, 1], 1)
        cur_delta = int(deg.max()) if eun.any() else 0
        if cur_delta == 0:
            break
        if cur_delta > ten_log_n:
            thresh = float(delta) ** (-(0.5 ** i))
        else:
            thresh = 1.1  # H_i = G_i
        in_h = jnp.asarray(erank01 <= thresh) & unk
        with ledger.shuffle(f"level_{i}_greedyMM", nbytes_of(g.edges)):
            # resolve the sampled subgraph completely (one AMPC launch)
            st, iters, q0, q1 = _mm_fixpoint(
                u, v, jnp.where(in_h, jrank, BIGF), n,
                jnp.where(in_h, jnp.int32(UNKNOWN), jnp.int32(OUT)))
            # edges of H_i resolved; commit IN edges, kill touched vertices
            new_in = (st == IN) & in_h
            estatus = jnp.where(new_in, IN, estatus)
            matched = jnp.zeros((n,), jnp.int32)
            matched = matched.at[jnp.where(estatus == IN, u, n)].set(1, mode="drop")
            matched = matched.at[jnp.where(estatus == IN, v, n)].set(1, mode="drop")
            dead = (estatus == UNKNOWN) & ((matched[u] == 1) | (matched[v] == 1))
            estatus = jnp.where(dead, OUT, estatus)
            # H_i \ M_i edges whose endpoints survive go back to G_{i+1}
        level_stats.append({"level": i, "delta": cur_delta,
                            "threshold": thresh,
                            "iters": int(jax.device_get(iters))})
    st = np.asarray(jax.device_get(estatus))
    return st == IN, {"levels": level_stats, "k": k,
                      "erank": erank01.astype(np.float32)}


def mm_ampc_vertex_process(g: UGraph, epsilon: float = 0.5, seed: int = 0,
                           ledger: Optional[RoundLedger] = None,
                           ) -> Tuple[np.ndarray, dict]:
    """Theorem 2 part 2: vertex-started truncated query process.

    Each launch gives every vertex a fresh budget of n^ε queries; decisions on
    an edge are applied only while at least one endpoint still has budget, so
    resolution is delayed — never altered — and the output is the exact LFMM.
    """
    ledger = ledger if ledger is not None else RoundLedger("ampc_mm_vertex")
    n, m = g.n, g.m
    rng = np.random.default_rng(seed)
    erank = rng.permutation(m).astype(np.float32)
    u = jnp.asarray(g.edges[:, 0]); v = jnp.asarray(g.edges[:, 1])
    jrank = jnp.asarray(erank)
    budget = max(4, int(np.ceil(n ** epsilon)))

    @functools.partial(jax.jit, static_argnames=())
    def launch(estatus):
        qcount0 = jnp.zeros((n,), jnp.int32)

        def cond(s):
            estatus, qcount, it, q = s
            unk = estatus == UNKNOWN
            active = (qcount[u] < budget) | (qcount[v] < budget)
            return jnp.any(unk & active) & (it < 4 * budget)

        def body(s):
            estatus, qcount, it, q = s
            active = (qcount[u] < budget) | (qcount[v] < budget)
            new, _ = _mm_wave(estatus, u, v, jrank, n, active_edge=active)
            unk = estatus == UNKNOWN
            # each unresolved active edge costs one query at each live endpoint
            cost = jnp.zeros((n,), jnp.int32)
            live = unk & active
            cost = cost.at[jnp.where(live, u, n)].add(1, mode="drop")
            cost = cost.at[jnp.where(live, v, n)].add(1, mode="drop")
            return new, qcount + cost, it + 1, q + live.sum()

        return jax.lax.while_loop(cond, body,
                                  (estatus, qcount0, jnp.int32(0), jnp.int32(0)))

    estatus = jnp.zeros((m,), jnp.int32)
    launches, total_q = 0, 0
    while bool(jax.device_get(jnp.any(estatus == UNKNOWN))) and launches < 64:
        with ledger.shuffle(f"vertex_process_{launches}", m):
            estatus, qcount, iters, q = launch(estatus)
            total_q += int(jax.device_get(q))
        launches += 1
    ledger.record_queries(total_q, total_q * 12, waves=launches)
    st = np.asarray(jax.device_get(estatus))
    return st == IN, {"launches": launches, "budget": budget,
                      "queries": total_q, "erank": erank}


def mm_mpc_rootset(g: UGraph, seed: int = 0,
                   ledger: Optional[RoundLedger] = None,
                   max_phases: int = 500) -> Tuple[np.ndarray, dict]:
    ledger = ledger if ledger is not None else RoundLedger("mpc_mm")
    n, m = g.n, g.m
    rng = np.random.default_rng(seed)
    erank = rng.permutation(m).astype(np.float32)
    u = jnp.asarray(g.edges[:, 0]); v = jnp.asarray(g.edges[:, 1])
    jrank = jnp.asarray(erank)

    @jax.jit
    def phase(estatus):
        new, _ = _mm_wave(estatus, u, v, jrank, n)
        return new, (new == UNKNOWN).sum()

    estatus = jnp.zeros((m,), jnp.int32)
    phases, remaining = 0, m
    nb = nbytes_of(g.edges)
    while remaining > 0 and phases < max_phases:
        with ledger.shuffle(f"rootset_mark_{phases}", nb):
            estatus, rem = phase(estatus)
        with ledger.shuffle(f"rootset_remove_{phases}", nb):
            remaining = int(jax.device_get(rem))
        phases += 1
    st = np.asarray(jax.device_get(estatus))
    return st == IN, {"phases": phases, "erank": erank}


# ==========================================================================
# Corollary 4.1 applications of the MM black box
# ==========================================================================
def mwm_greedy_ampc(g: UGraph, seed: int = 0,
                    ledger: Optional[RoundLedger] = None,
                    dht=None, snapshot=None) -> Tuple[np.ndarray, dict]:
    """1/2-approx maximum weight matching: greedy by decreasing weight
    (ties broken by a random permutation), via the AMPC MM fixpoint with
    weight-derived ranks injected through ``mm_ampc(erank=...)``.
    Returns (in_matching bool(m,), stats)."""
    assert g.weights is not None
    rng = np.random.default_rng(seed)
    tie = rng.permutation(g.m).astype(np.float64) / max(g.m, 1)
    # rank: ascending = processed first => sort by decreasing weight
    order = np.argsort(np.lexsort((tie, -g.weights.astype(np.float64))))
    erank = order.astype(np.float32)
    ledger = ledger if ledger is not None else RoundLedger("ampc_mwm")
    in_mm, st = mm_ampc(g, seed=seed, ledger=ledger, erank=erank, dht=dht,
                        snapshot=snapshot)
    w = float(g.weights[in_mm].sum())
    return in_mm, {"weight": w, **st}


def vertex_cover_2approx(g: UGraph, seed: int = 0,
                         ledger: Optional[RoundLedger] = None,
                         dht=None, snapshot=None) -> Tuple[np.ndarray, dict]:
    """2-approx minimum vertex cover = endpoints of a maximal matching."""
    in_mm, stats = mm_ampc(g, seed=seed, ledger=ledger, dht=dht,
                           snapshot=snapshot)
    cover = np.zeros(g.n, bool)
    cover[g.edges[in_mm, 0]] = True
    cover[g.edges[in_mm, 1]] = True
    return cover, {"cover_size": int(cover.sum()), **stats}


# ==========================================================================
# MSF (paper Section 3, Algorithm 2)
# ==========================================================================
def _msf_assemble(orig_eid, m, dmask, eids_h, q_h, jump_h, live_h, phases_h,
                  cases_h, budget, nt):
    """Sparse-path output assembly shared by the 5-shuffle and the fused
    session paths: union the Prim-discovered edges (tern eids mapped back
    through ``orig_eid``) into the dense-phase mask, and build the stats."""
    total_q = int(q_h)
    prim_eids = np.asarray(eids_h).ravel()
    prim_eids = prim_eids[prim_eids >= 0]
    orig = orig_eid[prim_eids]
    orig = orig[orig >= 0]
    mask = dmask.copy()
    if m:
        mask[orig] = True
    live_v = int(live_h)
    stats = {
        "path": "sparse",
        "budget": budget,
        "n_tern": nt,
        "queries": total_q,
        "avg_queries_per_vertex": total_q / max(nt, 1),
        "pointer_jump_iters": int(jump_h),
        "contracted_vertices": live_v,
        "shrink_factor": nt / max(live_v, 1),
        "dense_phases": int(phases_h),
        "stop_cases": {int(k): int(c) for k, c in zip(
            *np.unique(np.asarray(cases_h), return_counts=True))},
    }
    return mask, stats


def msf_ampc(g: UGraph, epsilon: float = 0.5, seed: int = 0,
             ledger: Optional[RoundLedger] = None,
             skip_ternarize_if_dense: bool = True,
             dht=None, snapshot=None) -> Tuple[np.ndarray, dict]:
    """Compute the MSF mask over g.edges.  Returns (mask, stats).

    ``snapshot`` switches to the fused session path: the ternarized
    adjacency (or the dense edge image) comes from the session's cached KV
    view — cold it is built under one ``WriteTernKV`` / ``WriteGraphKV``
    shuffle, warm it is free — and the whole solve then runs in a single
    ``MSF`` round (2 shuffles cold, 1 warm, vs the cold path's 5).  The
    rank permutation is still the *first* per-solve draw from ``seed``, so
    outputs are bit-identical to the snapshot-free path.
    """
    ledger = ledger if ledger is not None else RoundLedger("ampc_msf")
    assert g.weights is not None
    n, m = g.n, g.m
    rng = np.random.default_rng(seed)

    dense = skip_ternarize_if_dense and m >= n ** (1.0 + epsilon / 2.0)
    if dense:
        # Proposition 3.1 path: run the dense routine directly.
        if snapshot is not None:
            entries, snap_hit = snapshot.materialize_dense(ledger)
            u, v, w = entries["edge_u"], entries["edge_v"], entries["edge_w"]
            shuffle_nbytes = 0  # the write was accounted at view build
        else:
            u = jnp.asarray(g.edges[:, 0]); v = jnp.asarray(g.edges[:, 1])
            w = jnp.asarray(g.weights)
            shuffle_nbytes = nbytes_of(g.edges, g.weights)
        eid = jnp.arange(m, dtype=jnp.int32)
        valid = jnp.ones((m,), bool)
        with ledger.shuffle("DenseMSF", shuffle_nbytes):
            mask_dev, _, phases = boruvka_inround(u, v, w, eid, valid, n, m)
            col_dev = _collect_dev(dht, ledger, mask_dev.astype(jnp.int32))
            mask, phases_h = ledger.harvest((col_dev, phases))
            mask = np.asarray(mask).astype(bool)
        stats = {"phases": int(phases_h), "path": "dense"}
        if snapshot is not None:
            stats["snapshot"] = snapshot.stat(snap_hit)
        return mask, stats

    if snapshot is not None:
        # fused session path: read the ternarized view from the snapshot
        # cache, then run Prim -> jump -> contract -> Borůvka in ONE round
        entries, snap_hit = snapshot.materialize_tern(ledger)
        tg = entries["tg"]
        nt = tg.g.n
        rank = rng.permutation(nt).astype(np.float32)
        budget = max(2, int(np.ceil(nt ** (epsilon / 2.0))))
        with ledger.shuffle("MSF", 0):
            out_eids, hooks, cases, queries = truncated_prim(
                entries["nbr"], entries["nbw"], entries["nbe"],
                jnp.asarray(rank), budget)
            q_sum = queries.sum()
            ledger.record_queries_deferred(q_sum, q_sum * 36, waves=1)
            parent = jnp.where(hooks >= 0, hooks,
                               jnp.arange(nt, dtype=jnp.int32))
            roots, jump_iters = pointer_jump(parent)
            ledger.record_queries_deferred(jump_iters * nt,
                                           jump_iters * nt * 4, waves=1)
            cu, cv, cw, ceid, cvalid, live = contract_edges(
                entries["tu"], entries["tv"], entries["tw"],
                entries["teid"], jnp.ones((tg.g.m,), bool), roots)
            dmask_dev, _, phases = boruvka_inround(cu, cv, cw, ceid, cvalid,
                                                   nt, max(m, 1))
            col_dev = _collect_dev(dht, ledger, dmask_dev.astype(jnp.int32))
            (dmask, eids_h, q_h, jump_h, live_h, phases_h, cases_h) = \
                ledger.harvest((col_dev, out_eids, q_sum, jump_iters, live,
                                phases, cases))
            dmask = np.asarray(dmask).astype(bool)
        mask, stats = _msf_assemble(tg.orig_eid, m, dmask, eids_h, q_h,
                                    jump_h, live_h, phases_h, cases_h,
                                    budget, nt)
        stats["snapshot"] = snapshot.stat(snap_hit)
        return mask, stats

    # --- shuffle 1: SortGraph (ternarize + build sorted adjacency, write DHT)
    with ledger.shuffle("SortGraph", nbytes_of(g.edges, g.weights)):
        tg = ternarize(g)
        nbr, nbw, nbe = tg.g.padded_adj(3)
        nt = tg.g.n
        rank = rng.permutation(nt).astype(np.float32)
        budget = max(2, int(np.ceil(nt ** (epsilon / 2.0))))
    ledger.record_queries(0, 0, waves=0)

    # --- shuffle 2: PrimSearch (adaptive queries against the DHT snapshot)
    jn_nbr, jn_nbw, jn_nbe = jnp.asarray(nbr), jnp.asarray(nbw), jnp.asarray(nbe)
    jn_rank = jnp.asarray(rank)
    with ledger.shuffle("PrimSearch", 0):
        out_eids, hooks, cases, queries = truncated_prim(
            jn_nbr, jn_nbw, jn_nbe, jn_rank, budget)
        q_sum = queries.sum()
    row_bytes = 3 * (4 + 4 + 4)
    ledger.record_queries_deferred(q_sum, q_sum * row_bytes, waves=1)

    # --- shuffle 3: PointerJump (contract the hook forest, Prop 3.2)
    with ledger.shuffle("PointerJump", nbytes_of(hooks)):
        parent = jnp.where(hooks >= 0, hooks, jnp.arange(nt, dtype=jnp.int32))
        roots, jump_iters = pointer_jump(parent)
    ledger.record_queries_deferred(jump_iters * nt, jump_iters * nt * 4,
                                   waves=1)

    # --- shuffle 4: Contract (relabel + dedup on the ternarized edge list)
    tu = jnp.asarray(tg.g.edges[:, 0]); tv = jnp.asarray(tg.g.edges[:, 1])
    tw = jnp.asarray(tg.g.weights); teid = jnp.asarray(tg.orig_eid)
    with ledger.shuffle("Contract", nbytes_of(tg.g.edges, tg.g.weights)):
        cu, cv, cw, ceid, cvalid, live = contract_edges(
            tu, tv, tw, teid, jnp.ones((tg.g.m,), bool), roots)

    # --- shuffle 5: DenseMSF on the contracted graph, then the solve's
    # single harvest: every output array and deferred counter, one transfer
    with ledger.shuffle("DenseMSF", 0):
        dmask_dev, dlabels, phases = boruvka_inround(cu, cv, cw, ceid, cvalid,
                                                     nt, max(m, 1))
        col_dev = _collect_dev(dht, ledger, dmask_dev.astype(jnp.int32))
        (dmask, eids_h, q_h, jump_h, live_h, phases_h, cases_h) = \
            ledger.harvest((col_dev, out_eids, q_sum, jump_iters, live,
                            phases, cases))
        dmask = np.asarray(dmask).astype(bool)
    return _msf_assemble(tg.orig_eid, m, dmask, eids_h, q_h, jump_h, live_h,
                         phases_h, cases_h, budget, nt)


def msf_mpc_boruvka(g: UGraph, seed: int = 0,
                    ledger: Optional[RoundLedger] = None,
                    max_phases: int = 200) -> Tuple[np.ndarray, dict]:
    ledger = ledger if ledger is not None else RoundLedger("mpc_msf")
    n, m = g.n, g.m
    rng = np.random.default_rng(seed)
    u = jnp.asarray(g.edges[:, 0]); v = jnp.asarray(g.edges[:, 1])
    w = jnp.asarray(g.weights); eid = jnp.arange(m, dtype=jnp.int32)
    valid = jnp.ones((m,), bool)
    labels = jnp.arange(n, dtype=jnp.int32)
    mask = np.zeros(m, bool)
    phase_bytes = nbytes_of(g.edges, g.weights)
    phases = 0
    remaining = m
    while remaining > 0 and phases < max_phases:
        color = jnp.asarray(rng.random(n) < 0.5)
        # the paper's MPC algorithm performs 3 shuffles per contraction phase
        with ledger.shuffle(f"boruvka_minedge_{phases}", phase_bytes):
            pass
        with ledger.shuffle(f"boruvka_hook_{phases}", n * 4):
            labels, selected, valid, rem = _mpc_boruvka_phase(
                u, v, w, eid, valid, labels, color,
                jnp.zeros((m,), bool))
        with ledger.shuffle(f"boruvka_relabel_{phases}", phase_bytes):
            mask |= np.asarray(jax.device_get(selected))
            remaining = int(jax.device_get(rem))
        phases += 1
    return mask, {"phases": phases}


# ==========================================================================
# Connectivity (paper Theorem 1)
# ==========================================================================
def cc_ampc(g: UGraph, epsilon: float = 0.5, seed: int = 0,
            ledger: Optional[RoundLedger] = None,
            dht=None, snapshot=None) -> Tuple[np.ndarray, dict]:
    """Connected components; returns (labels(n,) canonical, stats).

    ``snapshot`` switches to the fused session path (see :func:`msf_ampc`):
    the unit-weight ternarization + first-slot map come from the session's
    ``tern_cc`` KV view (one ``WriteTernKV`` shuffle, cold only) and the
    solve runs in a single ``Connectivity`` round — 2 shuffles cold, 1
    warm, bit-identical labels.
    """
    ledger = ledger if ledger is not None else RoundLedger("ampc_cc")
    n, m = g.n, g.m
    if m == 0:
        stats = {"queries": 0}
        if snapshot is not None:
            # nothing to materialize; the trivial answer never hits the KV
            stats["snapshot"] = snapshot.stat(False)
        return np.arange(n, dtype=np.int64), stats
    rng = np.random.default_rng(seed)

    if snapshot is not None:
        entries, snap_hit = snapshot.materialize_tern(ledger, unit=True)
        tg = entries["tg"]
        nt = tg.g.n
        rank = rng.permutation(nt).astype(np.float32)
        budget = max(2, int(np.ceil(nt ** (epsilon / 2.0))))
        with ledger.shuffle("Connectivity", 0):
            out_eids, hooks, cases, queries = truncated_prim(
                entries["nbr"], entries["nbw"], entries["nbe"],
                jnp.asarray(rank), budget)
            q_sum = queries.sum()
            ledger.record_queries_deferred(q_sum, q_sum * 36, waves=1)
            parent = jnp.where(hooks >= 0, hooks,
                               jnp.arange(nt, dtype=jnp.int32))
            roots, jump_iters = pointer_jump(parent)
            cu, cv, cw, ceid, cvalid, live = contract_edges(
                entries["tu"], entries["tv"], entries["tw"],
                entries["teid"], jnp.ones((tg.g.m,), bool), roots)
            _, dlabels, phases = boruvka_inround(cu, cv, cw, ceid, cvalid,
                                                 nt, max(m, 1))
            # compose contractions: two genuine DHT reads of the label maps
            if dht is not None:
                final_tern = dht.lookup(dlabels, roots, ledger=ledger)
                orig_dev = dht.lookup(final_tern, entries["first_slot"],
                                      ledger=ledger)
            else:
                final_tern = jnp.take(dlabels, roots)
                orig_dev = jnp.take(final_tern, entries["first_slot"])
            orig_labels, q_h, jump_h, phases_h = \
                ledger.harvest((orig_dev, q_sum, jump_iters, phases))
            orig_labels = np.asarray(orig_labels).astype(np.int64)
        labels = _canonicalize(orig_labels)
        return labels, {
            "queries": int(q_h),
            "pointer_jump_iters": int(jump_h),
            "dense_phases": int(phases_h),
            "num_components": int(len(np.unique(labels))),
            "snapshot": snapshot.stat(snap_hit),
        }

    gw = UGraph(n, g.edges, np.arange(m, dtype=np.float32))  # unit-ish distinct
    with ledger.shuffle("SortGraph", nbytes_of(gw.edges)):
        tg = ternarize(gw)
        nbr, nbw, nbe = tg.g.padded_adj(3)
        nt = tg.g.n
        rank = rng.permutation(nt).astype(np.float32)
        budget = max(2, int(np.ceil(nt ** (epsilon / 2.0))))
        # first tern slot of each original vertex (node_of is sorted)
        first_slot = np.searchsorted(tg.node_of, np.arange(n))

    with ledger.shuffle("PrimSearch", 0):
        out_eids, hooks, cases, queries = truncated_prim(
            jnp.asarray(nbr), jnp.asarray(nbw), jnp.asarray(nbe),
            jnp.asarray(rank), budget)
        q_sum = queries.sum()
    ledger.record_queries_deferred(q_sum, q_sum * 36, waves=1)

    with ledger.shuffle("PointerJump", nbytes_of(hooks)):
        parent = jnp.where(hooks >= 0, hooks, jnp.arange(nt, dtype=jnp.int32))
        roots, jump_iters = pointer_jump(parent)

    tu = jnp.asarray(tg.g.edges[:, 0]); tv = jnp.asarray(tg.g.edges[:, 1])
    tw = jnp.asarray(tg.g.weights); teid = jnp.asarray(tg.orig_eid)
    with ledger.shuffle("Contract", nbytes_of(tg.g.edges)):
        cu, cv, cw, ceid, cvalid, live = contract_edges(
            tu, tv, tw, teid, jnp.ones((tg.g.m,), bool), roots)

    with ledger.shuffle("ForestConnectivity", 0):
        _, dlabels, phases = boruvka_inround(cu, cv, cw, ceid, cvalid, nt,
                                             max(m, 1))
        # compose contractions: two genuine DHT reads of the label maps
        if dht is not None:
            final_tern = dht.lookup(dlabels, roots, ledger=ledger)
            orig_dev = dht.lookup(final_tern,
                                  jnp.asarray(first_slot, jnp.int32),
                                  ledger=ledger)
        else:
            final_tern = jnp.take(dlabels, roots)
            orig_dev = jnp.take(final_tern, jnp.asarray(first_slot))
        orig_labels, q_h, jump_h, phases_h = \
            ledger.harvest((orig_dev, q_sum, jump_iters, phases))
        orig_labels = np.asarray(orig_labels).astype(np.int64)

    labels = _canonicalize(orig_labels)
    stats = {
        "queries": int(q_h),
        "pointer_jump_iters": int(jump_h),
        "dense_phases": int(phases_h),
        "num_components": int(len(np.unique(labels))),
    }
    return labels, stats


def cc_mpc_hash_to_min(g: UGraph, ledger: Optional[RoundLedger] = None,
                       max_phases: int = 200) -> Tuple[np.ndarray, dict]:
    ledger = ledger if ledger is not None else RoundLedger("mpc_cc")
    n = g.n
    u = jnp.asarray(g.edges[:, 0]); v = jnp.asarray(g.edges[:, 1])
    labels = jnp.arange(n, dtype=jnp.int32)
    phases = 0
    nb = nbytes_of(g.edges)
    while phases < max_phases:
        with ledger.shuffle(f"h2m_join_{phases}", nb):
            labels, changed = _h2m_phase(u, v, labels)
        with ledger.shuffle(f"h2m_update_{phases}", n * 4):
            ch = bool(jax.device_get(changed))
        phases += 1
        if not ch:
            break
    labels = _canonicalize(np.asarray(jax.device_get(labels)).astype(np.int64))
    return labels, {"phases": phases,
                    "num_components": int(len(np.unique(labels)))}


# ==========================================================================
# 1-vs-2-Cycle (paper Section 5.6)
# ==========================================================================
def one_vs_two_ampc(g: UGraph, p: float = 1.0 / 64, seed: int = 0,
                    ledger: Optional[RoundLedger] = None,
                    max_steps: Optional[int] = None,
                    snapshot=None) -> Tuple[int, dict]:
    """Returns (num_cycles, stats).

    ``snapshot`` reads the cycle adjacency from the session's ``cycle_adj``
    KV view instead of rebuilding it under the ``WriteKV`` shuffle; the
    sample set is still drawn per solve (same rng order), so the answer is
    identical — 2 shuffles cold, 1 warm.
    """
    ledger = ledger if ledger is not None else RoundLedger("ampc_1v2c")
    n = g.n
    rng = np.random.default_rng(seed)
    snap_stat = None
    if snapshot is not None:
        entries, snap_hit = snapshot.materialize_cycle(ledger)
        nbr = entries["cycle_nbr"]
        sampled_np = rng.random(n) < p
        if not sampled_np.any():
            sampled_np[rng.integers(n)] = True
        sampled = jnp.asarray(sampled_np)
        snap_stat = snapshot.stat(snap_hit)
    else:
        with ledger.shuffle("WriteKV", nbytes_of(g.edges)):
            nbr = jnp.asarray(cycle_adjacency(g))
            sampled_np = rng.random(n) < p
            # guarantee at least one sample (paper: w.h.p. argument)
            if not sampled_np.any():
                sampled_np[rng.integers(n)] = True
            sampled = jnp.asarray(sampled_np)
    ms = max_steps or int(min(n + 1, np.ceil(8 * np.log(max(n, 2)) / p)))
    with ledger.shuffle("SampleWalk", int(sampled_np.sum()) * 4):
        ncomp, steps, ok = ledger.harvest(_walk_and_count(nbr, sampled, ms))
        ncomp, total_steps, ok = int(ncomp), int(steps), bool(ok)
    ledger.record_queries(total_steps, total_steps * 12, waves=1)
    if not ok:
        raise RuntimeError("walk budget exceeded; increase p or max_steps")
    stats = {"samples": int(sampled_np.sum()),
             "walk_steps": total_steps, "max_steps": ms}
    if snap_stat is not None:
        stats["snapshot"] = snap_stat
    return ncomp, stats


def one_vs_two_mpc(g: UGraph, seed: int = 0,
                   ledger: Optional[RoundLedger] = None) -> Tuple[int, dict]:
    """CC-LocalContraction MPC baseline (Section 5.6): each phase removes the
    rank-local-minima of every cycle and reconnects; 3 shuffles per phase,
    O(log n) phases; the residual graph is finished in memory (the paper
    switches to a single machine below 5e7 edges)."""
    ledger = ledger if ledger is not None else RoundLedger("mpc_1v2c")
    n = g.n
    rng = np.random.default_rng(seed)
    nbr = cycle_adjacency(g)
    a = jnp.asarray(nbr[:, 0]); b = jnp.asarray(nbr[:, 1])
    rank = jnp.asarray(rng.permutation(n).astype(np.float32))
    parent = jnp.arange(n, dtype=jnp.int32)
    alive = jnp.ones((n,), bool)
    phases, remaining = 0, n
    nb = nbytes_of(g.edges)
    shrink = []
    while remaining > 0 and phases < 200:
        prev = remaining
        with ledger.shuffle(f"lc_minima_{phases}", nb):
            a, b, parent, alive, rem = _local_contraction_phase(
                a, b, parent, alive, rank)
        with ledger.shuffle(f"lc_reconnect_{phases}", nb):
            remaining = int(jax.device_get(rem))
        with ledger.shuffle(f"lc_relabel_{phases}", n * 4):
            shrink.append(prev / max(remaining, 1))
        phases += 1
    # in-memory finish: pointer-jump parents to roots
    roots, _ = pointer_jump(parent)
    ncomp = int(len(np.unique(np.asarray(jax.device_get(roots)))))
    return ncomp, {"phases": phases, "shrink_per_phase": shrink}


# ==========================================================================
# Registry entries — the engine's dispatch table
# ==========================================================================
@problem("mis", model="ampc", output="vertex_mask", aliases=("ampc-mis",),
         table3_shuffles=2,
         summary="LFMIS by in-round dependency fixpoint (Fig 1)")
def _p_mis(ctx, g, **opts):
    return mis_ampc(g, seed=ctx.seed, ledger=ctx.ledger, dht=ctx.dht, **opts)


@problem("mis-mpc", model="mpc", output="vertex_mask", baseline_of="mis",
         summary="MPC rootset baseline, 2 shuffles/phase (Fig 2)")
def _p_mis_mpc(ctx, g, **opts):
    return mis_mpc_rootset(g, seed=ctx.seed, ledger=ctx.ledger, **opts)


@problem("matching", model="ampc", output="edge_mask",
         aliases=("mm", "maximal-matching"), table3_shuffles=2,
         summary="LFMM by in-round edge fixpoint (Section 5.4)")
def _p_mm(ctx, g, **opts):
    return mm_ampc(g, seed=ctx.seed, ledger=ctx.ledger, dht=ctx.dht, **opts)


@problem("matching-levels", model="ampc", output="edge_mask",
         summary="Algorithm 4: O(log log Δ) geometric sampling levels")
def _p_mm_levels(ctx, g, **opts):
    return mm_ampc_levels(g, seed=ctx.seed, ledger=ctx.ledger, **opts)


@problem("matching-vertex-process", model="ampc", output="edge_mask",
         summary="Theorem 2.2: n^ε-budget truncated vertex query process")
def _p_mm_vertex(ctx, g, **opts):
    return mm_ampc_vertex_process(g, epsilon=ctx.epsilon, seed=ctx.seed,
                                  ledger=ctx.ledger, **opts)


@problem("matching-mpc", model="mpc", output="edge_mask",
         baseline_of="matching",
         summary="MPC rootset baseline, 2 shuffles/phase")
def _p_mm_mpc(ctx, g, **opts):
    return mm_mpc_rootset(g, seed=ctx.seed, ledger=ctx.ledger, **opts)


@problem("weighted-matching", model="ampc", output="edge_mask",
         aliases=("mwm",), needs_weights=True, table3_shuffles=2,
         summary="Corollary 4.1: greedy 1/2-approx MWM via erank injection")
def _p_mwm(ctx, g, **opts):
    return mwm_greedy_ampc(g, seed=ctx.seed, ledger=ctx.ledger, dht=ctx.dht,
                           **opts)


@problem("vertex-cover", model="ampc", output="vertex_mask",
         summary="Corollary 4.1: 2-approx vertex cover = V(maximal matching)")
def _p_vc(ctx, g, **opts):
    return vertex_cover_2approx(g, seed=ctx.seed, ledger=ctx.ledger,
                                dht=ctx.dht, **opts)


@problem("msf", model="ampc", output="edge_mask", needs_weights=True,
         table3_shuffles=5,
         summary="Algorithm 2: 5-shuffle truncated-Prim MSF")
def _p_msf(ctx, g, **opts):
    return msf_ampc(g, epsilon=ctx.epsilon, seed=ctx.seed, ledger=ctx.ledger,
                    dht=ctx.dht, **opts)


@problem("msf-kkt", model="ampc", output="edge_mask", needs_weights=True,
         summary="Algorithm 3: KKT sample + F-light filter + MSF")
def _p_msf_kkt(ctx, g, **opts):
    from ..core.kkt_filter import msf_kkt
    return msf_kkt(g, epsilon=ctx.epsilon, seed=ctx.seed, ledger=ctx.ledger,
                   **opts)


@problem("msf-mpc", model="mpc", output="edge_mask", needs_weights=True,
         baseline_of="msf",
         summary="MPC red/blue Borůvka baseline, 3 shuffles/phase")
def _p_msf_mpc(ctx, g, **opts):
    return msf_mpc_boruvka(g, seed=ctx.seed, ledger=ctx.ledger, **opts)


@problem("connectivity", model="ampc", output="labels", aliases=("cc",),
         table3_shuffles=5,
         summary="Theorem 1: MSF on unit weights + forest connectivity")
def _p_cc(ctx, g, **opts):
    return cc_ampc(g, epsilon=ctx.epsilon, seed=ctx.seed, ledger=ctx.ledger,
                   dht=ctx.dht, **opts)


@problem("connectivity-mpc", model="mpc", output="labels",
         baseline_of="connectivity",
         summary="MPC hash-to-min label propagation baseline")
def _p_cc_mpc(ctx, g, **opts):
    return cc_mpc_hash_to_min(g, ledger=ctx.ledger, **opts)


@problem("one-vs-two", model="ampc", output="count", aliases=("1v2c",),
         needs_cycles=True, table3_shuffles=2,
         summary="Section 5.6: adaptive cycle walk, the AMPC/MPC separation")
def _p_1v2(ctx, g, **opts):
    return one_vs_two_ampc(g, seed=ctx.seed, ledger=ctx.ledger, **opts)


@problem("one-vs-two-mpc", model="mpc", output="count",
         baseline_of="one-vs-two", needs_cycles=True,
         summary="CC-LocalContraction MPC baseline, 3 shuffles/phase")
def _p_1v2_mpc(ctx, g, **opts):
    return one_vs_two_mpc(g, seed=ctx.seed, ledger=ctx.ledger, **opts)


# ==========================================================================
# Batched adapters — AmpcEngine.solve_many, one vmapped launch per bucket
# ==========================================================================
# Each adapter takes (bctx: engine.BatchSolveContext, batch: GraphBatch) and
# returns one (output, stats) per graph, in batch order.  Invariants:
#
#   * outputs are bit-identical to sequential ``solve`` on the same engine
#     seed: each lane pads with inert edges/vertices and uses the graph's
#     *own* (unpadded) rank permutation, so the fixpoint trajectory over the
#     real vertices/edges is exactly the sequential one;
#   * the traced solver is memoized per (problem, backend, bucket) through
#     ``bctx.cache``; all graphs after the first occupant of a bucket ride
#     the same compiled program (stats["solver_cache"]);
#   * per-graph ledgers mirror the sequential shuffle structure, with this
#     graph's own bytes and its mask's share of the batched DHT traffic.


def _cache_stat(key, hit: bool, slot: int) -> dict:
    # slot 0 of a cold bucket pays the trace; every later occupant is a hit
    return {"key": key, "hit": bool(hit or slot > 0)}


def _per_graph_ranks(batch, seed: int):
    """Per-graph vertex rank permutations, padded to n_bucket.

    Each graph draws from ``default_rng(seed)`` exactly like the sequential
    solver; padding vertices get ranks above every real rank (they are
    isolated, so the value never matters)."""
    B, nb = len(batch), batch.n_bucket
    ranks = np.zeros((B, nb), np.float32)
    for b, g in enumerate(batch.graphs):
        rng = np.random.default_rng(seed)
        ranks[b, :g.n] = rng.permutation(g.n).astype(np.float32)
        ranks[b, g.n:] = np.arange(g.n, nb, dtype=np.float32)
    return ranks


def _build_mis_solver(n: int):
    return jax.jit(jax.vmap(
        lambda s, r, rank, ok: _mis_fixpoint_masked(s, r, rank, n, ok)))


@batched_impl("mis")
def mis_ampc_batched(bctx, batch, caching: bool = True):
    """Batched MIS: one masked-fixpoint launch over the whole bucket."""
    B, nb = len(batch), batch.n_bucket
    senders, receivers, edge_ok = batch.padded_symmetric()
    ranks = _per_graph_ranks(batch, bctx.seed)
    for b, g in enumerate(batch.graphs):
        bctx.ledgers[b].record_shuffle("DirectEdges+WriteKV",
                                       nbytes_of(g.edges) * 2)
    key = bctx.solver_key(batch)
    solver, hit = bctx.cache.get_or_build(
        key, lambda: _build_mis_solver(nb), occupants=B)
    t0 = time.perf_counter()
    status_b, iters_b, q0_b, q1_b = solver(
        jnp.asarray(senders), jnp.asarray(receivers), jnp.asarray(ranks),
        jnp.asarray(edge_ok))
    # CollectOutputs: one batched DHT read, per-graph queries split by mask
    keys = np.broadcast_to(np.arange(nb, dtype=np.int32), (B, nb))
    out_b = bctx.dht.lookup_many(status_b, keys, ledgers=bctx.ledgers,
                                 key_mask=batch.node_mask)
    # the bucket's one transfer: outputs + every ledger's deferred counters
    status_h, iters, q0, q1 = harvest_many(
        bctx.ledgers, (out_b, iters_b, q0_b, q1_b))
    status_h = np.asarray(status_h)
    dt = time.perf_counter() - t0
    outs = []
    for b, g in enumerate(batch.graphs):
        led = bctx.ledgers[b]
        led.record_shuffle("IsInMIS", g.n * 4, seconds=dt / B)
        qn, qd, it = int(q0[b]), int(q1[b]), int(iters[b])
        queries = qd if caching else qn
        led.record_queries(queries, queries * 8, waves=it,
                           deduped_away=(qn - qd) if caching else 0)
        status = status_h[b, :g.n]
        assert not (status == UNKNOWN).any()
        outs.append((status == IN,
                     {"fixpoint_iters": it, "queries_nodedup": qn,
                      "queries_dedup": qd,
                      "cache_savings_factor": qn / max(qd, 1),
                      "solver_cache": _cache_stat(key, hit, b)}))
    return outs


def _build_mm_solver(n: int):
    return jax.jit(jax.vmap(
        lambda u, v, rank, st0: _mm_fixpoint(u, v, rank, n, st0)))


def _mm_batched_launch(bctx, batch, eranks, caching: bool = True):
    """Shared batched greedy-MM launch (matching / mwm / vertex-cover).

    ``eranks`` is one unpadded rank array per graph (the Corollary-4.1
    injection point); padding edges start OUT so they never join or block.
    The compiled fixpoint is shared across every problem that rides it —
    the cache key is scoped to ``"matching"``, not the caller's name.
    """
    B, nb, mb = len(batch), batch.n_bucket, batch.m_bucket
    u = batch.edges[:, :, 0]
    v = batch.edges[:, :, 1]
    ranks = np.full((B, mb), np.inf, np.float32)
    for b, er in enumerate(eranks):
        ranks[b, :er.shape[0]] = er
    estatus0 = np.where(batch.edge_mask, np.int32(UNKNOWN),
                        np.int32(OUT)).astype(np.int32)
    for b, g in enumerate(batch.graphs):
        bctx.ledgers[b].record_shuffle("SortEdges+WriteKV",
                                       nbytes_of(g.edges) * 2)
    key = ("matching", bctx.backend_name, nb, mb)
    solver, hit = bctx.cache.get_or_build(
        key, lambda: _build_mm_solver(nb), occupants=B)
    t0 = time.perf_counter()
    estatus_b, iters_b, q0_b, q1_b = solver(
        jnp.asarray(u), jnp.asarray(v), jnp.asarray(ranks),
        jnp.asarray(estatus0))
    keys = np.broadcast_to(np.arange(mb, dtype=np.int32), (B, mb))
    out_b = bctx.dht.lookup_many(estatus_b, keys, ledgers=bctx.ledgers,
                                 key_mask=batch.edge_mask)
    estatus_h, iters, q0, q1 = harvest_many(
        bctx.ledgers, (out_b, iters_b, q0_b, q1_b))
    estatus_h = np.asarray(estatus_h)
    dt = time.perf_counter() - t0
    outs = []
    for b, g in enumerate(batch.graphs):
        led = bctx.ledgers[b]
        led.record_shuffle("IsInMM", g.m, seconds=dt / B)
        qn, qd, it = int(q0[b]), int(q1[b]), int(iters[b])
        queries = qd if caching else qn
        led.record_queries(queries, queries * 12, waves=it,
                           deduped_away=(qn - qd) if caching else 0)
        estatus = estatus_h[b, :g.m]
        outs.append((estatus == IN,
                     {"fixpoint_iters": it, "queries_nodedup": qn,
                      "queries_dedup": qd, "erank": eranks[b],
                      "solver_cache": _cache_stat(key, hit, b)}))
    return outs


@batched_impl("matching")
def mm_ampc_batched(bctx, batch, caching: bool = True):
    """Batched greedy maximal matching over per-graph random edge ranks."""
    eranks = []
    for g in batch.graphs:
        rng = np.random.default_rng(bctx.seed)
        eranks.append(rng.permutation(g.m).astype(np.float32))
    return _mm_batched_launch(bctx, batch, eranks, caching=caching)


@batched_impl("weighted-matching")
def mwm_greedy_ampc_batched(bctx, batch, caching: bool = True):
    """Batched 1/2-approx MWM: decreasing-weight eranks into the MM launch."""
    eranks = []
    for g in batch.graphs:
        rng = np.random.default_rng(bctx.seed)
        tie = rng.permutation(g.m).astype(np.float64) / max(g.m, 1)
        order = np.argsort(np.lexsort((tie, -g.weights.astype(np.float64))))
        eranks.append(order.astype(np.float32))
    outs = _mm_batched_launch(bctx, batch, eranks, caching=caching)
    return [(in_mm, {"weight": float(g.weights[in_mm].sum()), **st})
            for g, (in_mm, st) in zip(batch.graphs, outs)]


@batched_impl("vertex-cover")
def vertex_cover_2approx_batched(bctx, batch, caching: bool = True):
    """Batched 2-approx vertex cover: endpoints of the batched MM."""
    outs = mm_ampc_batched(bctx, batch, caching=caching)
    results = []
    for g, (in_mm, st) in zip(batch.graphs, outs):
        cover = np.zeros(g.n, bool)
        cover[g.edges[in_mm, 0]] = True
        cover[g.edges[in_mm, 1]] = True
        results.append((cover, {"cover_size": int(cover.sum()), **st}))
    return results


def _build_msf_sparse_solver(ntb: int, mb: int, capacity: int):
    """Vmapped sparse-MSF pipeline for one ternarized bucket shape.

    ``capacity`` is the bucket-max Prim budget: every lane shares the
    compiled buffer size while stopping at its own traced ``budget``
    (bit-identical per ``truncated_prim_capped``).  ``mb`` is the bucket's
    *original* edge capacity — the Borůvka mask is over original edge ids
    (``teid``), exactly like the sequential path."""
    def one(nbr, nbw, nbe, rank, budget, nmask, tu, tv, tw, teid, emask):
        out_eids, hooks, cases, queries = truncated_prim_capped(
            nbr, nbw, nbe, rank, budget, capacity)
        # padded tern vertices exhaust on their first frontier pop; mask
        # their unit query out of the per-graph total
        q_sum = jnp.where(nmask, queries, 0).sum()
        parent = jnp.where(hooks >= 0, hooks,
                           jnp.arange(ntb, dtype=jnp.int32))
        roots, jump_iters = pointer_jump(parent)
        cu, cv, cw, ceid, cvalid, live = contract_edges(
            tu, tv, tw, teid, emask, roots)
        dmask, _, phases = boruvka_core(cu, cv, cw, ceid, cvalid, ntb, mb)
        return (dmask.astype(jnp.int32), out_eids, q_sum, jump_iters,
                live, phases, cases)

    return jax.jit(jax.vmap(one))


def _build_msf_dense_solver(nb: int, mb: int):
    def one(u, v, w, emask):
        eid = jnp.arange(mb, dtype=jnp.int32)
        dmask, _, phases = boruvka_core(u, v, w, eid, emask, nb, mb)
        return dmask.astype(jnp.int32), phases

    return jax.jit(jax.vmap(one))


@batched_impl("msf")
def msf_ampc_batched(bctx, batch, skip_ternarize_if_dense: bool = True):
    """Batched MSF: lanes split by the sequential dense/sparse predicate.

    Sparse lanes run one vmapped truncated-Prim -> pointer-jump ->
    contract -> Borůvka launch over a shared :func:`ternarize_batch`
    bucket; dense lanes (``m >= n^(1+eps/2)``) run one vmapped Borůvka
    launch, mirroring the sequential Proposition-3.1 shortcut.  Each lane
    pads with isolated tern vertices / invalid edges and keeps its own
    rank permutation and budget, so outputs are bit-identical to
    sequential ``solve``; per-graph ledgers mirror the sequential 5- (or
    1-) shuffle structure, and the whole bucket still materializes through
    exactly one ``harvest_many`` transfer.
    """
    B, mb = len(batch), batch.m_bucket
    eps = bctx.epsilon
    dense_set = set(
        b for b, g in enumerate(batch.graphs)
        if skip_ternarize_if_dense and g.m >= g.n ** (1.0 + eps / 2.0))
    dense_idx = sorted(dense_set)
    sparse_idx = [b for b in range(B) if b not in dense_set]

    t0 = time.perf_counter()
    sparse_extra = dense_extra = None
    if sparse_idx:
        tb = ternarize_batch([batch.graphs[b] for b in sparse_idx])
        Bs, ntb = len(tb), tb.nt_bucket
        ranks = np.zeros((Bs, ntb), np.float32)
        budgets = np.zeros((Bs,), np.int32)
        for j, t in enumerate(tb.terns):
            nt = t.g.n
            rng = np.random.default_rng(bctx.seed)
            ranks[j, :nt] = rng.permutation(nt).astype(np.float32)
            ranks[j, nt:] = np.arange(nt, ntb, dtype=np.float32)
            budgets[j] = max(2, int(np.ceil(nt ** (eps / 2.0))))
        capacity = int(budgets.max())
        for b in sparse_idx:
            g = batch.graphs[b]
            bctx.ledgers[b].record_shuffle(
                "SortGraph", nbytes_of(g.edges, g.weights))
        skey = bctx.solver_key(batch,
                               ("sparse", ntb, tb.mt_bucket, capacity))
        ssolver, shit = bctx.cache.get_or_build(
            skey, lambda: _build_msf_sparse_solver(ntb, mb, capacity),
            occupants=Bs)
        (dmask_b, eids_b, q_b, jump_b, live_b, phases_b, cases_b) = ssolver(
            jnp.asarray(tb.nbr), jnp.asarray(tb.nbw), jnp.asarray(tb.nbe),
            jnp.asarray(ranks), jnp.asarray(budgets),
            jnp.asarray(tb.node_mask), jnp.asarray(tb.edges[:, :, 0]),
            jnp.asarray(tb.edges[:, :, 1]), jnp.asarray(tb.weights),
            jnp.asarray(tb.orig_eid), jnp.asarray(tb.edge_mask))
        # per-lane deferred traffic (prim, then pointer-jump) queued on
        # each graph's ledger before the bucket's one harvest
        for j, b in enumerate(sparse_idx):
            nt = tb.terns[j].g.n
            led = bctx.ledgers[b]
            led.record_queries_deferred(q_b[j], q_b[j] * 36, waves=1)
            led.record_queries_deferred(jump_b[j] * nt, jump_b[j] * nt * 4,
                                        waves=1)
        keys = np.broadcast_to(np.arange(mb, dtype=np.int32), (Bs, mb))
        col_b = bctx.dht.lookup_many(
            dmask_b, keys, ledgers=[bctx.ledgers[b] for b in sparse_idx],
            key_mask=batch.edge_mask[np.asarray(sparse_idx)])
        sparse_extra = (col_b, eids_b, q_b, jump_b, live_b, phases_b,
                        cases_b)
    if dense_idx:
        didx = np.asarray(dense_idx)
        demask = batch.edge_mask[didx]
        dkey = bctx.solver_key(batch, ("dense",))
        dsolver, dhit = bctx.cache.get_or_build(
            dkey, lambda: _build_msf_dense_solver(batch.n_bucket, mb),
            occupants=len(dense_idx))
        dmaskd_b, dphases_b = dsolver(
            jnp.asarray(batch.edges[didx, :, 0]),
            jnp.asarray(batch.edges[didx, :, 1]),
            jnp.asarray(batch.weights[didx]), jnp.asarray(demask))
        keys = np.broadcast_to(np.arange(mb, dtype=np.int32),
                               (len(dense_idx), mb))
        dcol_b = bctx.dht.lookup_many(
            dmaskd_b, keys, ledgers=[bctx.ledgers[b] for b in dense_idx],
            key_mask=demask)
        dense_extra = (dcol_b, dphases_b)

    # the bucket's one transfer: both sub-launches' outputs and every
    # ledger's deferred counters
    sparse_h, dense_h = harvest_many(bctx.ledgers,
                                     (sparse_extra, dense_extra))
    dt = time.perf_counter() - t0

    outs = [None] * B
    if sparse_idx:
        (col_h, eids_h, q_h, jump_h, live_h, phases_h, cases_h) = sparse_h
        col_h = np.asarray(col_h)
        eids_h = np.asarray(eids_h)
        cases_h = np.asarray(cases_h)
        for j, b in enumerate(sparse_idx):
            g = batch.graphs[b]
            t = tb.terns[j]
            nt = t.g.n
            led = bctx.ledgers[b]
            led.record_queries(0, 0, waves=0)
            led.record_shuffle("PrimSearch", 0)
            led.record_shuffle("PointerJump", nt * 4)
            led.record_shuffle("Contract", nbytes_of(t.g.edges, t.g.weights))
            led.record_shuffle("DenseMSF", 0, seconds=dt / B)
            mask, stats = _msf_assemble(
                t.orig_eid, g.m, col_h[j, :g.m].astype(bool),
                eids_h[j, :nt], q_h[j], jump_h[j], live_h[j], phases_h[j],
                cases_h[j, :nt], int(budgets[j]), nt)
            stats["solver_cache"] = _cache_stat(skey, shit, j)
            outs[b] = (mask, stats)
    if dense_idx:
        dcol_h, dphases_h = dense_h
        dcol_h = np.asarray(dcol_h)
        for j, b in enumerate(dense_idx):
            g = batch.graphs[b]
            bctx.ledgers[b].record_shuffle(
                "DenseMSF", nbytes_of(g.edges, g.weights), seconds=dt / B)
            outs[b] = (dcol_h[j, :g.m].astype(bool),
                       {"phases": int(dphases_h[j]), "path": "dense",
                        "solver_cache": _cache_stat(dkey, dhit, j)})
    return outs


def _build_cc_solver(n: int):
    return jax.jit(jax.vmap(
        lambda u, v, ok: _cc_fixpoint_masked(u, v, ok, n)))


@batched_impl("connectivity")
def cc_ampc_batched(bctx, batch):
    """Batched connectivity via in-round min-label doubling (2 shuffles).

    The sequential solver runs the paper's 5-shuffle truncated-Prim
    pipeline; that pipeline's per-graph ternarized shapes do not bucket, so
    the batched path instead resolves labels by masked hash-to-min run to
    fixpoint against one snapshot.  Outputs are identical after
    canonicalization (component labels are min-vertex-id in both paths);
    the ledger reflects the 2-shuffle batched pipeline.
    """
    B, nb = len(batch), batch.n_bucket
    u = batch.edges[:, :, 0]
    v = batch.edges[:, :, 1]
    for b, g in enumerate(batch.graphs):
        bctx.ledgers[b].record_shuffle("SortGraph+WriteKV",
                                       nbytes_of(g.edges))
    key = bctx.solver_key(batch)
    solver, hit = bctx.cache.get_or_build(
        key, lambda: _build_cc_solver(nb), occupants=B)
    t0 = time.perf_counter()
    labels_b, iters_b, q0_b, q1_b = solver(
        jnp.asarray(u), jnp.asarray(v), jnp.asarray(batch.edge_mask))
    keys = np.broadcast_to(np.arange(nb, dtype=np.int32), (B, nb))
    out_b = bctx.dht.lookup_many(labels_b, keys, ledgers=bctx.ledgers,
                                 key_mask=batch.node_mask)
    labels_h, iters, q0, q1 = harvest_many(
        bctx.ledgers, (out_b, iters_b, q0_b, q1_b))
    labels_h = np.asarray(labels_h)
    dt = time.perf_counter() - t0
    outs = []
    for b, g in enumerate(batch.graphs):
        led = bctx.ledgers[b]
        led.record_shuffle("LabelFixpoint", g.n * 4, seconds=dt / B)
        qn, qd, it = int(q0[b]), int(q1[b]), int(iters[b])
        led.record_queries(qd, qd * 8, waves=it, deduped_away=qn - qd)
        labels = _canonicalize(labels_h[b, :g.n].astype(np.int64))
        outs.append((labels,
                     {"label_prop_iters": it, "queries": qd,
                      "queries_nodedup": qn,
                      "num_components": int(len(np.unique(labels))),
                      "solver_cache": _cache_stat(key, hit, b)}))
    return outs


def _build_1v2_solver(n: int, max_steps: int):
    return jax.jit(
        lambda nbr, sampled: _walk_and_count_batch(nbr, sampled, max_steps, n))


@batched_impl("one-vs-two")
def one_vs_two_ampc_batched(bctx, batch, p: float = 1.0 / 64,
                            max_steps: Optional[int] = None):
    """Batched 1-vs-2-cycle: one vmapped walk launch per bucket.

    Padding vertices self-loop and are marked sampled, so each contributes
    exactly 2 walk steps and 1 component — both subtracted per graph.  The
    static walk budget is the bucket maximum of the per-graph budgets (it
    only bounds the in-round chase; successful walks stop at the next
    sample regardless), and is part of the solver cache key.
    """
    B, nb = len(batch), batch.n_bucket
    nbrs = np.zeros((B, nb, 2), np.int32)
    sampled = np.zeros((B, nb), bool)
    n_samples = np.zeros(B, np.int64)
    ms = 1
    for b, g in enumerate(batch.graphs):
        nbrs[b, :g.n] = cycle_adjacency(g)
        pads = np.arange(g.n, nb, dtype=np.int32)
        nbrs[b, g.n:, 0] = pads
        nbrs[b, g.n:, 1] = pads
        rng = np.random.default_rng(bctx.seed)
        s = rng.random(g.n) < p
        if not s.any():
            s[rng.integers(g.n)] = True
        sampled[b, :g.n] = s
        sampled[b, g.n:] = True
        n_samples[b] = int(s.sum())
        ms = max(ms, max_steps or
                 int(min(g.n + 1, np.ceil(8 * np.log(max(g.n, 2)) / p))))
        bctx.ledgers[b].record_shuffle("WriteKV", nbytes_of(g.edges))
    key = bctx.solver_key(batch, ("max_steps", ms))
    solver, hit = bctx.cache.get_or_build(
        key, lambda: _build_1v2_solver(nb, ms), occupants=B)
    t0 = time.perf_counter()
    ncomp_b, steps_b, ok_b = solver(jnp.asarray(nbrs), jnp.asarray(sampled))
    ncomp, steps, ok = harvest_many(bctx.ledgers, (ncomp_b, steps_b, ok_b))
    ncomp, steps, ok = np.asarray(ncomp), np.asarray(steps), np.asarray(ok)
    dt = time.perf_counter() - t0
    outs = []
    for b, g in enumerate(batch.graphs):
        if not bool(ok[b]):
            raise RuntimeError("walk budget exceeded; increase p or "
                               f"max_steps (graph {batch.indices[b]})")
        n_pad = nb - g.n
        real_steps = int(steps[b]) - 2 * n_pad
        led = bctx.ledgers[b]
        led.record_shuffle("SampleWalk", int(n_samples[b]) * 4,
                           seconds=dt / B)
        led.record_queries(real_steps, real_steps * 12, waves=1)
        outs.append((int(ncomp[b]) - n_pad,
                     {"samples": int(n_samples[b]),
                      "walk_steps": real_steps, "max_steps": ms,
                      "solver_cache": _cache_stat(key, hit, b)}))
    return outs
