"""Ternarization (Algorithm 2, line 2): bound degrees by 3.

Every vertex v with deg(v) > 3 is replaced by a cycle of deg(v) dummy
vertices; the i-th incident edge of v attaches to the i-th cycle vertex.
Dummy cycle edges get weight "bottom" (strictly below the lightest real edge)
so they always enter the MSF first and never displace real MSF edges; they are
removed from the output (their edge id is -1).

Host-side numpy — this is a data-layout transformation, part of the input
pipeline of the MSF job.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..graph.coo import UGraph


@dataclasses.dataclass
class TernGraph:
    g: UGraph                 # ternarized graph (weights include dummy edges)
    orig_eid: np.ndarray      # (m_tern,) original edge id, -1 for dummy edges
    node_of: np.ndarray       # (n_tern,) original vertex of each tern vertex
    n_orig: int
    m_orig: int


def ternarize(g: UGraph) -> TernGraph:
    assert g.weights is not None, "ternarize expects a weighted graph"
    n, m = g.n, g.m
    deg = g.degrees()
    slots = np.maximum(deg, 1)
    expand = deg > 3
    n_slots = np.where(expand, slots, 1).astype(np.int64)
    offset = np.zeros(n + 1, np.int64)
    np.cumsum(n_slots, out=offset[1:])
    n_tern = int(offset[-1])

    # position of each directed edge inside its source's adjacency list
    indptr, indices, w, eid = g.csr()
    pos_in_adj = np.arange(len(indices), dtype=np.int64) - np.repeat(indptr[:-1], np.diff(indptr))
    src = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    # per undirected edge, slot at each endpoint
    slot_u = np.zeros(m, np.int64)
    slot_v = np.zeros(m, np.int64)
    # each undirected eid appears exactly twice in the directed view
    first_seen = np.full(m, -1, np.int64)
    for p in range(len(indices)):
        e = eid[p]
        if first_seen[e] < 0:
            first_seen[e] = p
            slot_u[e] = pos_in_adj[p]
        else:
            slot_v[e] = pos_in_adj[p]
    del src

    u, v = g.edges[:, 0].astype(np.int64), g.edges[:, 1].astype(np.int64)
    nu = offset[u] + np.where(expand[u], slot_u, 0)
    nv = offset[v] + np.where(expand[v], slot_v, 0)
    real_edges = np.stack([nu, nv], axis=1)

    # dummy cycle edges for expanded vertices
    exp_ids = np.where(expand)[0]
    dummy_u, dummy_v = [], []
    for x in exp_ids:
        base, d = offset[x], deg[x]
        idx = base + np.arange(d)
        dummy_u.append(idx)
        dummy_v.append(base + (np.arange(d) + 1) % d)
    if dummy_u:
        dummy_edges = np.stack([np.concatenate(dummy_u), np.concatenate(dummy_v)], axis=1)
    else:
        dummy_edges = np.zeros((0, 2), np.int64)

    lightest = float(g.weights.min()) if m else 0.0
    bot = lightest - 1.0
    k = dummy_edges.shape[0]
    dummy_w = bot - np.arange(k, dtype=np.float32) / max(k, 1)  # distinct, all < lightest

    edges = np.concatenate([real_edges, dummy_edges]).astype(np.int32)
    weights = np.concatenate([g.weights, dummy_w]).astype(np.float32)
    orig = np.concatenate([np.arange(m, dtype=np.int32), np.full(k, -1, np.int32)])

    node_of = np.repeat(np.arange(n, dtype=np.int32), n_slots)
    tg = UGraph(n_tern, edges, weights)
    return TernGraph(tg, orig, node_of, n, m)
