"""AmpcEngine session API: every registered problem × both DHT backends.

Asserts (a) oracle parity for each problem on each backend, (b) that
``AmpcResult.ledger["shuffles"]`` reproduces the paper's Table-3
constant-round counts for the AMPC algorithms, and (c) the registry /
deprecation surface.
"""
import numpy as np
import pytest

from repro.ampc import (AmpcEngine, AmpcResult, LocalDht, RoutedDht,
                        get_problem, problem_names, resolve_backend)
from repro.core import oracle
from repro.core.rounds import RoundLedger
from repro.graph import generators as gen
from repro.graph.coo import UGraph

BACKENDS = ["local", "routed"]

# one small graph family per problem kind; sized so the routed shard_map
# programs compile quickly on the single-device CI host
G_PLAIN = lambda: gen.erdos_renyi(120, 3.0, seed=2)
G_CYCLES = lambda: gen.two_cycles(60)


def _engine(backend):
    return AmpcEngine(dht_backend=backend, epsilon=0.5, seed=0)


def _input_for(spec):
    if spec.needs_cycles:
        return G_CYCLES()
    g = G_PLAIN()
    return g.with_random_weights(3) if spec.needs_weights else g


def _opts_for(spec):
    # canonical sparse-path opts so Table-3 counts are deterministic
    if spec.name == "msf":
        return {"skip_ternarize_if_dense": False}
    if spec.name.startswith("one-vs-two"):
        return {} if spec.model == "mpc" else {"p": 1 / 8}
    return {}


def _oracle_check(spec, g, res):
    """Problem-specific ground-truth comparison."""
    name, out = spec.name, res.output
    if name in ("mis", "mis-mpc"):
        rng = np.random.default_rng(0)
        want = oracle.greedy_mis(g, rng.permutation(g.n).astype(np.float32))
        assert np.array_equal(out, want)
    elif name in ("matching", "matching-levels", "matching-vertex-process",
                  "matching-mpc", "weighted-matching"):
        want = oracle.greedy_mm(g, res.stats["erank"])
        assert np.array_equal(out, want)
        assert oracle.is_maximal_matching(g, out)
    elif name == "vertex-cover":
        mm = oracle.greedy_mm(g, res.stats["erank"])
        cover = np.zeros(g.n, bool)
        cover[g.edges[mm, 0]] = True
        cover[g.edges[mm, 1]] = True
        assert np.array_equal(out, cover)
    elif name in ("msf", "msf-kkt", "msf-mpc"):
        want, _ = oracle.kruskal_msf(g)
        assert np.array_equal(out, want)
    elif name in ("connectivity", "connectivity-mpc"):
        assert np.array_equal(out, oracle.connected_components(g))
    elif name in ("one-vs-two", "one-vs-two-mpc"):
        assert out == 2
    else:  # new problems must add an oracle here
        raise AssertionError(f"no oracle check for {name}")


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("problem", sorted(problem_names()))
def test_solve_matches_oracle(problem, backend):
    spec = get_problem(problem)
    g = _input_for(spec)
    res = _engine(backend).solve(g, problem, **_opts_for(spec))
    assert isinstance(res, AmpcResult)
    assert res.model == spec.model
    assert res.backend == backend
    _oracle_check(spec, g, res)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("problem",
                         [n for n in problem_names("ampc")
                          if get_problem(n).table3_shuffles is not None])
def test_table3_constant_rounds(problem, backend):
    """Table 3: AMPC algorithms use a constant number of shuffles, on both
    backends, with the DHT traffic recorded in the same ledger keys."""
    spec = get_problem(problem)
    g = _input_for(spec)
    res = _engine(backend).solve(g, problem, **_opts_for(spec))
    assert res.ledger["shuffles"] == spec.table3_shuffles
    assert res.ledger["dht_queries"] > 0
    assert res.ledger["dht_bytes"] > 0
    assert res.ledger["dht_overflows"] == 0
    assert res.shuffles == res.ledger["shuffles"]


def test_mpc_baselines_use_more_rounds():
    eng = _engine("local")
    for prob in ("mis", "matching", "msf", "connectivity", "one-vs-two"):
        spec = get_problem(prob)
        base = eng.baseline_for(prob)
        assert base is not None, f"no MPC baseline registered for {prob}"
        g = _input_for(spec)
        ra = eng.solve(g, prob, **_opts_for(spec))
        rm = eng.solve(g, base, **_opts_for(get_problem(base)))
        assert rm.shuffles > ra.shuffles, (prob, ra.shuffles, rm.shuffles)


def test_registry_aliases_and_errors():
    assert get_problem("mm").name == "matching"
    assert get_problem("cc").name == "connectivity"
    assert get_problem("mwm").name == "weighted-matching"
    with pytest.raises(KeyError, match="unknown problem"):
        get_problem("nope")
    # a rejected registration (colliding alias) must leave the registry
    # untouched — no half-registered problem
    from repro.ampc import registry as reg
    before = problem_names()
    with pytest.raises(ValueError, match="collides"):
        reg.problem("evil", model="ampc", output="count",
                    aliases=("mis",))(lambda ctx, g: (0, {}))
    assert problem_names() == before
    with pytest.raises(ValueError, match="needs edge weights"):
        _engine("local").solve(G_PLAIN(), "msf")
    with pytest.raises(ValueError, match="unknown dht_backend"):
        AmpcEngine(dht_backend="rdma")


def test_backend_resolution():
    assert isinstance(resolve_backend("local"), LocalDht)
    assert isinstance(resolve_backend("routed"), RoutedDht)
    custom = LocalDht()
    assert resolve_backend(custom) is custom
    # a DhtBackend instance passes straight through the engine
    eng = AmpcEngine(dht_backend=custom)
    assert eng.dht is custom


def test_engine_seed_epsilon_overrides():
    g = G_PLAIN()
    r0 = _engine("local").solve(g, "mis")
    r1 = AmpcEngine(seed=7).solve(g, "mis")
    r2 = AmpcEngine(seed=7).solve(g, "mis", seed=0)
    # verified offline: seeds 0 and 7 give different MIS on this graph
    assert not np.array_equal(r0.output, r1.output)
    assert np.array_equal(r0.output, r2.output)  # per-solve override wins


def test_erank_injection_replaces_monkey_wiring():
    """mm_ampc(erank=...) is the public rank-override path; the greedy over
    any rank array matches the sequential oracle over the same ranks."""
    from repro.ampc.solvers import mm_ampc
    g = G_PLAIN()
    rng = np.random.default_rng(5)
    erank = rng.permutation(g.m).astype(np.float32)
    got, st = mm_ampc(g, ledger=RoundLedger("t"), erank=erank)
    assert np.array_equal(got, oracle.greedy_mm(g, erank))
    assert np.array_equal(st["erank"], erank)
    with pytest.raises(AssertionError):
        mm_ampc(g, erank=np.zeros(3, np.float32))  # wrong shape


def test_deprecated_shims_still_work_and_warn():
    import warnings
    from repro.core import mis as mis_mod
    from repro.ampc.deprecation import _warned
    g = G_PLAIN()
    _warned.discard("repro.core.mis.mis_ampc")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        got, _ = mis_mod.mis_ampc(g, seed=0)
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    want = _engine("local").solve(g, "mis").output
    assert np.array_equal(got, want)


def test_result_ledger_is_summary_dict():
    res = _engine("local").solve(G_PLAIN(), "mis")
    for key in ("shuffles", "bytes_shuffled", "dht_queries", "dht_bytes",
                "dht_query_waves", "dedup_savings", "dht_overflows",
                "wall_time_s", "phase_times"):
        assert key in res.ledger, key
    assert res.raw_ledger.shuffles == res.ledger["shuffles"]
