"""GCN (Kipf & Welling, arXiv:1609.02907) — gcn-cora config:
2 layers, d_hidden=16, symmetric normalization, node classification."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import GraphBatch, degree, gather, init_linear, linear, scatter_sum


@dataclasses.dataclass(frozen=True)
class GCNConfig:
    name: str = "gcn-cora"
    n_layers: int = 2
    d_feat: int = 1433
    d_hidden: int = 16
    n_classes: int = 7
    dtype: object = jnp.float32


def init_params(cfg: GCNConfig, key):
    keys = jax.random.split(key, cfg.n_layers)
    dims = [cfg.d_feat] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    return {"layers": [init_linear(keys[i], dims[i], dims[i + 1], cfg.dtype)
                       for i in range(cfg.n_layers)]}


def forward(cfg: GCNConfig, params, batch: GraphBatch):
    n = batch.n_nodes
    # symmetric normalization with self-loops: deg includes self
    deg = degree(batch.receivers, n, batch.edge_mask) + 1.0
    dinv = jax.lax.rsqrt(jnp.maximum(deg, 1e-9))
    x = batch.node_feat.astype(cfg.dtype)
    for i, layer in enumerate(params["layers"]):
        h = linear(layer, x)
        msg = gather(h * dinv[:, None], batch.senders)
        agg = scatter_sum(msg, batch.receivers, n, batch.edge_mask)
        x = (agg + h * dinv[:, None]) * dinv[:, None]   # includes self-loop
        if i < len(params["layers"]) - 1:
            x = jax.nn.relu(x)
    return x  # (N, n_classes) logits


def loss_fn(cfg: GCNConfig, params, batch: GraphBatch):
    logits = forward(cfg, params, batch).astype(jnp.float32)
    labels = batch.labels
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    nll = jnp.where(batch.node_mask, logz - gold, 0).sum() / \
        jnp.maximum(batch.node_mask.sum(), 1)
    return nll, {"nll": nll}
