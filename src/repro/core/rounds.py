"""Round / query / byte accounting for AMPC and MPC executions.

The paper measures (Table 3, Fig 3, Fig 9):
  * shuffles  — materialized rounds (Flume stages writing to durable storage);
  * bytes shuffled — data written by shuffles;
  * DHT communication — bytes of key-value store queries + answers;
  * query count — number of KV lookups.

Here a "shuffle" is a materialized jitted-program launch whose outputs are
committed (and, under the fault-tolerant runtime, checkpointed).  Adaptive
in-round query waves performed via ``lax.while_loop`` count queries/DHT bytes
but not shuffles — exactly the AMPC accounting.  MPC baselines call
``ledger.shuffle`` once per phase instead.

Observability wiring (``repro.obs``): a ledger may carry a ``tracer`` and a
``metrics`` registry.  Every shuffle then becomes a span (named
``shuffle:<name>``, carrying its bytes) and every counter update lands in
the engine-wide metric series (``shuffles_total``, ``dht_queries_total``,
…) labeled by ``algorithm``.  Both default to disabled no-ops, so a bare
``RoundLedger`` behaves exactly as before.

Raw-string event accumulation is gated behind ``record_events``: the
structured trace supersedes the strings, and long-lived engines serving
``solve_many`` traffic must not grow an unbounded list per solve (the
engine creates bucket-loop ledgers with ``record_events=False``).

Deferred (device-resident) accounting: a ledger created with
``deferred=True`` queues DHT-traffic records on the device instead of
host-syncing per lookup.  ``ShardedDHT`` and the solvers hand
:meth:`RoundLedger.record_queries_deferred` raw device scalars
(``n_unique``, overflow counts, iteration counters) without calling
``device_get``/``int()`` on them; the engine materializes every pending
record — together with the solver outputs — in **one** ``jax.device_get``
per solve (:meth:`RoundLedger.harvest`) or per ``solve_many`` bucket
(:func:`harvest_many`).  Harvest folds each record through the same
counter/trace/metrics apply path the eager ``record_queries`` uses, with
``dht_queries`` events back-filled onto the span that was open at record
time, so the resulting ledger and trace are bit-identical to the eager
path.  A bare ``RoundLedger()`` keeps ``deferred=False`` and behaves
exactly as before: counters readable immediately after every lookup.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

# Test hook for the one-harvest-per-solve rule: when set, called with the
# ledger (or ledger list) each time a harvest performs its single
# ``jax.device_get``.  Tests install a counting callback to assert a warm
# solve syncs exactly once.
HARVEST_HOOK: Any = None


class DeviceCounters:
    """Pending on-device DHT-traffic records for one ledger.

    Each hot-path call queues one record — five scalars (queries, nbytes,
    waves, deduped_away, overflow), any of which may still be an unread
    device array — plus the tracer span open at record time.  Nothing
    touches the host until :meth:`RoundLedger.harvest` /
    :func:`harvest_many` drains every record in a single transfer.

    Records are kept individually (rather than folded into one running
    device vector) so harvest can replay them one-by-one through the
    eager apply path: per-wave ``dht_queries`` trace events and metric
    increments come out identical to eager mode, not collapsed into one.
    """

    __slots__ = ("records",)

    def __init__(self):
        # [((queries, nbytes, waves, deduped_away, overflow), span)]
        self.records: List = []

    def add(self, record, span=None) -> None:
        self.records.append((record, span))

    def drain(self) -> List:
        records, self.records = self.records, []
        return records

    def __len__(self):
        return len(self.records)

    def __repr__(self):
        return f"DeviceCounters(pending={len(self.records)})"


@dataclasses.dataclass
class RoundLedger:
    algorithm: str = ""
    shuffles: int = 0
    bytes_shuffled: int = 0
    dht_queries: int = 0
    dht_bytes: int = 0
    dht_query_waves: int = 0
    dedup_savings: int = 0  # queries avoided by the caching optimization
    dht_overflows: int = 0  # routed-router capacity overflows (0 = exact)
    wall_time_s: float = 0.0
    phase_times: Dict[str, float] = dataclasses.field(default_factory=dict)
    events: List[str] = dataclasses.field(default_factory=list)
    # observability hooks (repro.obs); None => disabled
    tracer: Any = dataclasses.field(repr=False, compare=False, default=None)
    metrics: Any = dataclasses.field(repr=False, compare=False, default=None)
    record_events: bool = dataclasses.field(compare=False, default=True)
    # deferred accounting: queue device scalars, harvest once per solve
    deferred: bool = dataclasses.field(compare=False, default=False)
    device: DeviceCounters = dataclasses.field(
        repr=False, compare=False, default_factory=DeviceCounters)

    # -- shuffle (materialized round) -------------------------------------
    @contextlib.contextmanager
    def shuffle(self, name: str, nbytes: int = 0):
        tracer = self.tracer
        t0 = time.perf_counter()
        if tracer is not None and tracer.enabled:
            with tracer.span(f"shuffle:{name}", algorithm=self.algorithm,
                             nbytes=int(nbytes)):
                yield
        else:
            yield
        self._count_shuffle(name, nbytes, time.perf_counter() - t0)

    def record_shuffle(self, name: str, nbytes: int = 0,
                       seconds: float = 0.0):
        """Record one materialized round without timing a ``with`` block.

        Used by batched (``solve_many``) launches, where one physical launch
        serves many per-graph ledgers: each ledger records its own shuffle
        entry with its share of the bytes and wall time.  With a tracer the
        share becomes a retroactive span under the current open span.
        """
        tracer = self.tracer
        if tracer is not None and tracer.enabled:
            tracer.record_span(f"shuffle:{name}", dur_s=seconds,
                               algorithm=self.algorithm, nbytes=int(nbytes))
        self._count_shuffle(name, nbytes, seconds)

    def _count_shuffle(self, name: str, nbytes: int, seconds: float):
        self.shuffles += 1
        self.bytes_shuffled += int(nbytes)
        self.wall_time_s += seconds
        self.phase_times[name] = self.phase_times.get(name, 0.0) + seconds
        if self.record_events:
            self.events.append(f"shuffle:{name}:{nbytes}B:{seconds:.4f}s")
        if self.metrics is not None:
            self.metrics.counter(
                "shuffles_total", labelnames=("algorithm",)).inc(
                    1, algorithm=self.algorithm)
            self.metrics.counter(
                "bytes_shuffled_total", labelnames=("algorithm",)).inc(
                    int(nbytes), algorithm=self.algorithm)

    # -- DHT traffic -------------------------------------------------------
    def record_queries(self, n_queries: int, nbytes: int, waves: int = 1,
                       deduped_away: int = 0, overflow: int = 0):
        """Eagerly record one wave of DHT traffic (host values)."""
        self._apply_queries(int(n_queries), int(nbytes), int(waves),
                            int(deduped_away), int(overflow))

    def record_queries_deferred(self, n_queries, nbytes, waves=1,
                                deduped_away=0, overflow=0):
        """Record DHT traffic without leaving the device.

        Arguments may be raw device scalars; on a ``deferred=True`` ledger
        they are queued untouched and materialized later by
        :meth:`harvest` in one transfer.  On an eager ledger this
        degrades to an immediate :meth:`record_queries` (one transfer
        now), preserving bare-ledger semantics — counters are readable
        right after the lookup that produced them.
        """
        record = (n_queries, nbytes, waves, deduped_away, overflow)
        if not self.deferred:
            import jax  # host-sync: ok — eager ledger, sync by contract
            self._apply_queries(*(int(x) for x in jax.device_get(record)))
            return
        span = None
        tracer = self.tracer
        if tracer is not None and tracer.enabled:
            span = tracer.current_span()
        self.device.add(record, span)

    def harvest(self, extra=None):
        """Materialize every pending deferred record in one transfer.

        ``extra`` is an optional pytree of device arrays the caller wants
        pulled in the same ``jax.device_get`` (solver outputs, iteration
        counters); its host copy is returned.  This is the *one* host
        sync a deferred solve performs; :data:`HARVEST_HOOK` fires once
        per actual transfer so tests can count syncs.  With nothing
        pending and no ``extra`` the call is free — no transfer at all.

        On an eager (``deferred=False``) ledger this instead mirrors the
        pre-deferral sync pattern: one blocking ``jax.device_get`` per
        ``extra`` leaf, exactly like the per-value ``int(device_get(...))``
        / ``np.asarray(device_get(...))`` calls the solvers used to make.
        That keeps ``deferred_accounting=False`` a faithful "today's hot
        path" baseline for the ``dht_hot_path`` benchmark rather than a
        half-deferred hybrid that batches the final transfer anyway.
        """
        import jax

        records = self.device.drain()
        if not records and extra is None:
            return None
        if HARVEST_HOOK is not None:
            HARVEST_HOOK(self)
        if not self.deferred and extra is not None:
            # records were already applied eagerly at record time, so only
            # extra remains; transfer leaf by leaf (seed sync pattern)
            leaves, treedef = jax.tree.flatten(extra)
            host = [jax.device_get(leaf) for leaf in leaves]
            return jax.tree.unflatten(treedef, host)
        host_records, host_extra = jax.device_get(
            ([rec for rec, _ in records], extra))
        for host_rec, (_, span) in zip(host_records, records):
            self._apply_queries(*(int(x) for x in host_rec), span=span)
        return host_extra

    def _apply_queries(self, n_queries: int, nbytes: int, waves: int,
                       deduped_away: int, overflow: int, span=None):
        """Fold one wave of host-side counts into counters/trace/metrics.

        ``span`` is the span that was open when a deferred record was
        queued: the ``dht_queries`` event is back-filled onto it so a
        harvested trace matches the eager one event-for-event.
        """
        self.dht_queries += n_queries
        self.dht_bytes += nbytes
        self.dht_query_waves += waves
        self.dedup_savings += deduped_away
        self.dht_overflows += overflow
        tracer = self.tracer
        if tracer is not None and tracer.enabled:
            kw = dict(queries=n_queries, nbytes=nbytes, waves=waves,
                      deduped_away=deduped_away, overflow=overflow)
            if span is not None:
                span.event("dht_queries", **kw)
            else:
                tracer.event("dht_queries", **kw)
        m = self.metrics
        if m is not None:
            labels = {"labelnames": ("algorithm",)}
            kw = {"algorithm": self.algorithm}
            m.counter("dht_queries_total", **labels).inc(n_queries, **kw)
            m.counter("dht_bytes_total", **labels).inc(nbytes, **kw)
            m.counter("dht_query_waves_total", **labels).inc(waves, **kw)
            if deduped_away:
                m.counter("dedup_savings_total", **labels).inc(
                    deduped_away, **kw)
            if overflow:
                m.counter("dht_overflows_total", **labels).inc(
                    overflow, **kw)

    def summary(self) -> Dict:
        if self.device.records:  # safety net: a forgotten harvest
            self.harvest()
        return {
            "algorithm": self.algorithm,
            "shuffles": self.shuffles,
            "bytes_shuffled": self.bytes_shuffled,
            "dht_queries": self.dht_queries,
            "dht_bytes": self.dht_bytes,
            "dht_query_waves": self.dht_query_waves,
            "dedup_savings": self.dedup_savings,
            "dht_overflows": self.dht_overflows,
            "wall_time_s": round(self.wall_time_s, 4),
            "phase_times": {k: round(v, 4) for k, v in self.phase_times.items()},
        }


def harvest_many(ledgers: Sequence[Optional[RoundLedger]], extra=None):
    """Harvest several deferred ledgers in one ``jax.device_get``.

    The ``solve_many`` counterpart of :meth:`RoundLedger.harvest`: one
    bucket launch accumulates pending records on every per-graph ledger,
    and the engine drains them all — plus the batched outputs in
    ``extra`` — with a single transfer.  Returns ``extra``'s host copy.
    """
    import jax

    ledgers = [led for led in ledgers if led is not None]
    pending = [led.device.drain() for led in ledgers]
    if not any(pending) and extra is None:
        return None
    if HARVEST_HOOK is not None:
        HARVEST_HOOK(ledgers)
    if not any(pending) and not any(led.deferred for led in ledgers):
        # all-eager bucket: mirror the pre-deferral per-leaf sync pattern
        # (see RoundLedger.harvest) so eager solve_many stays a faithful
        # baseline
        leaves, treedef = jax.tree.flatten(extra)
        return jax.tree.unflatten(treedef,
                                  [jax.device_get(leaf) for leaf in leaves])
    host_pending, host_extra = jax.device_get(
        ([[rec for rec, _ in records] for records in pending], extra))
    for led, host_records, records in zip(ledgers, host_pending, pending):
        for host_rec, (_, span) in zip(host_records, records):
            led._apply_queries(*(int(x) for x in host_rec), span=span)
    return host_extra


def nbytes_of(*arrays) -> int:
    total = 0
    for a in arrays:
        if a is None:
            continue
        total += a.size * a.dtype.itemsize
    return int(total)
