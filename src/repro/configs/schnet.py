"""schnet: 3 interactions, d_hidden=64, 300 RBF, cutoff 10."""
from ..models.gnn.schnet import SchNetConfig
CONFIG = SchNetConfig()
SMOKE = SchNetConfig(d_hidden=16, n_rbf=8)
