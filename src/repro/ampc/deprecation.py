"""Once-per-symbol deprecation warnings for the pre-engine call surface."""
from __future__ import annotations

import warnings

_warned = set()


def warn_once(old: str, new: str):
    """Emit one DeprecationWarning per process for ``old``.

    The legacy module-level functions keep working (they are thin shims over
    :mod:`repro.ampc.solvers`), but new code should go through
    ``AmpcEngine.solve`` — see src/repro/ampc/README.md.
    """
    if old in _warned:
        return
    _warned.add(old)
    warnings.warn(f"{old} is deprecated; use {new} (see src/repro/ampc/"
                  "README.md)", DeprecationWarning, stacklevel=3)
