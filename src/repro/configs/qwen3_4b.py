"""qwen3-4b: 36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936,
qk_norm + GQA."""
from .lm_archs import QWEN3_4B as CONFIG, smoke
SMOKE = smoke(CONFIG)
