"""Figures 5-8 + Table 4 analog: wall-clock AMPC vs MPC on the benchmark
suite (single-host CPU execution of the same jitted programs; the paper's
absolute times are datacenter-specific, the *ratios* and round counts are
the reproducible claims).  Every solve goes through one AmpcEngine."""
from __future__ import annotations

from repro.ampc import AmpcEngine

from .common import CYCLES, DEFAULT_GRAPHS, GRAPHS, fmt_table
from .registry import bench
from repro.graph import generators as gen


@bench("runtimes", takes_graphs=True,
       quick_kwargs={"graph_names": ["rmat12", "er13"],
                     "cycles": {"2x2e3": 2000}},
       summary="Fig 5-8: wall-clock AMPC vs MPC speedups")
def run(graph_names=None, cycles=None):
    names = graph_names or list(DEFAULT_GRAPHS)
    eng = AmpcEngine(seed=0)
    rows = []
    for gname in names:
        g = GRAPHS[gname]()
        gw = g.with_random_weights(0)
        t_amis = eng.solve(g, "mis").wall_time_s
        t_mmis = eng.solve(g, "mis-mpc").wall_time_s
        t_amm = eng.solve(g, "matching").wall_time_s
        t_mmm = eng.solve(g, "matching-mpc").wall_time_s
        t_amsf = eng.solve(gw, "msf",
                           skip_ternarize_if_dense=False).wall_time_s
        t_mmsf = eng.solve(gw, "msf-mpc").wall_time_s
        rows.append([gname,
                     f"{t_amis:.2f}/{t_mmis:.2f} ({t_mmis/t_amis:.1f}x)",
                     f"{t_amm:.2f}/{t_mmm:.2f} ({t_mmm/t_amm:.1f}x)",
                     f"{t_amsf:.2f}/{t_mmsf:.2f} ({t_mmsf/t_amsf:.1f}x)"])
    out = fmt_table(["graph", "MIS a/m (speedup)", "MM a/m (speedup)",
                     "MSF a/m (speedup)"], rows)
    print(out)

    crows = []
    for cname, k in (cycles or CYCLES).items():
        g2 = gen.two_cycles(k)
        ra = eng.solve(g2, "one-vs-two", p=1 / 64)
        rm = eng.solve(g2, "one-vs-two-mpc")
        assert ra.output == 2 and rm.output == 2
        crows.append([cname, f"{ra.wall_time_s:.2f}", f"{rm.wall_time_s:.2f}",
                      f"{rm.wall_time_s/ra.wall_time_s:.1f}x"])
    cout = fmt_table(["cycles", "AMPC s", "MPC s", "speedup"], crows)
    print("\n" + cout)
    print("\npaper: MIS 2.31-3.18x, MM 1.16-1.72x, MSF 2.6-7.19x, "
          "1v2c 3.40-9.87x (100 machines, RDMA)")
    return {"rows": rows, "cycle_rows": crows,
            "markdown": out + "\n\n" + cout}


if __name__ == "__main__":
    run()
