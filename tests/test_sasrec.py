"""SASRec smoke: encode/score/retrieve/train shapes, no NaNs, loss learns."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import REGISTRY
from repro.models import sasrec
from repro.data.recsys import RecStreamConfig, batch_at_step


@pytest.fixture(scope="module")
def setup():
    cfg = REGISTRY["sasrec"].smoke_config
    params = sasrec.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_encode_and_score(setup):
    cfg, params = setup
    rc = RecStreamConfig(cfg.n_items, cfg.seq_len, batch=4)
    seq, pos, neg = batch_at_step(rc, 0)
    states = sasrec.encode(cfg, params, jnp.asarray(seq))
    assert states.shape == (4, cfg.seq_len, cfg.embed_dim)
    user = states[:, -1]
    cands = jnp.asarray(np.random.default_rng(0).integers(
        1, cfg.n_items, (4, 64)).astype(np.int32))
    scores = sasrec.score_candidates(cfg, params, user, cands)
    assert scores.shape == (4, 64)
    assert np.isfinite(np.asarray(scores)).all()


def test_retrieval_full_table(setup):
    cfg, params = setup
    user = jnp.asarray(np.random.default_rng(1).standard_normal(
        (2, cfg.embed_dim)).astype(np.float32))
    scores = sasrec.retrieval_scores(cfg, params, user)
    assert scores.shape == (2, cfg.n_items)


def test_bpr_loss_decreases(setup):
    cfg, params = setup
    rc = RecStreamConfig(cfg.n_items, cfg.seq_len, batch=16)

    @jax.jit
    def step(p, s, po, ne):
        loss, grads = jax.value_and_grad(
            lambda pp: sasrec.loss_fn(cfg, pp, s, po, ne)[0])(p)
        p = jax.tree.map(lambda a, g: a - 0.05 * g, p, grads)
        return p, loss

    losses = []
    for it in range(8):
        seq, pos, neg = batch_at_step(rc, it % 2)
        params, loss = step(params, jnp.asarray(seq), jnp.asarray(pos),
                            jnp.asarray(neg))
        losses.append(float(loss))
    assert losses[-1] < losses[0]
    assert np.isfinite(losses).all()
