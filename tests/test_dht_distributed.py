"""routed_lookup (shard_map all_to_all DHT router) on 8 virtual devices.

Runs in a subprocess because XLA device count must be set before jax init
(and the rest of the suite must see 1 device).
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.core import dht

    assert len(jax.devices()) == 8
    mesh = jax.make_mesh((8,), ("data",))
    n, q = 64, 64
    rng = np.random.default_rng(0)
    values = jnp.asarray(rng.random((n, 4)).astype(np.float32))
    keys_np = rng.integers(0, n, q).astype(np.int32)
    keys_np[5] = keys_np[6] = keys_np[7]   # duplicates to exercise dedup
    keys = jnp.asarray(keys_np)
    from jax.sharding import NamedSharding, PartitionSpec as P
    values = jax.device_put(values, NamedSharding(mesh, P("data", None)))
    keys = jax.device_put(keys, NamedSharding(mesh, P("data")))
    out, n_unique, overflow = dht.routed_lookup(values, keys, mesh, "data")
    ref = np.asarray(values)[keys_np]
    assert np.allclose(np.asarray(out), ref), "routed lookup mismatch"
    assert int(overflow) == 0
    assert 0 < int(n_unique) <= q
    # no-dedup path
    out2, nu2, ov2 = dht.routed_lookup(values, keys, mesh, "data", dedup=False)
    assert np.allclose(np.asarray(out2), ref)
    assert int(nu2) >= int(n_unique)

    # ShardedDHT routed path: same ledger accounting as the local path
    from repro.core.rounds import RoundLedger
    led_r, led_l = RoundLedger("routed"), RoundLedger("local")
    d_r = dht.ShardedDHT(values, ledger=led_r, mesh=mesh, axis_name="data")
    d_l = dht.ShardedDHT(values, ledger=led_l)
    out_r = d_r.lookup(keys)
    out_l = d_l.lookup(keys)
    assert np.allclose(np.asarray(out_r), np.asarray(out_l))
    assert led_r.dht_overflows == 0
    assert led_r.dht_query_waves == led_l.dht_query_waves == 1
    assert led_r.dht_queries > 0 and led_l.dht_queries > 0
    # routed counts per-shard distinct keys; never fewer than global distinct
    assert led_r.dht_queries >= led_l.dht_queries
    assert led_r.dedup_savings <= led_l.dedup_savings

    # engine smoke on 8 devices: routed backend end-to-end
    from repro.ampc import AmpcEngine
    from repro.graph import generators as gen
    from repro.core import oracle
    g = gen.erdos_renyi(96, 3.0, seed=1)
    res = AmpcEngine(mesh=mesh, dht_backend="routed").solve(g, "mis")
    want = oracle.greedy_mis(
        g, np.random.default_rng(0).permutation(g.n).astype(np.float32))
    assert np.array_equal(res.output, want)
    assert res.ledger["shuffles"] == 2 and res.ledger["dht_overflows"] == 0
    print("ROUTED_OK", int(n_unique), int(nu2))
""")


def test_routed_lookup_8_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "ROUTED_OK" in r.stdout
