"""Minimum spanning forest in constant adaptive rounds (paper Section 3).

Pieces:
  * ``truncated_prim``  — Algorithm 1: per-vertex rank-truncated Prim search,
    vmapped over all vertices (each vertex = one AMPC "machine task"); three
    stopping conditions (budget, exhaustion, lower-rank hook).
  * ``pointer_jump``    — Proposition 3.2 forest contraction (in-round
    doubling on the immutable hook snapshot).
  * ``contract_edges``  — relabel + self-loop removal + min-weight dedup.
  * ``boruvka_inround`` — DenseMSF stand-in: Borůvka hook-and-contract run
    entirely inside one launch (AMPC adaptivity), used for the dense phase.
  * ``msf_ampc``        — Algorithm 2 driver (5 materialized shuffles, matching
    the paper's Table 3 accounting: SortGraph, PrimSearch, PointerJump,
    Contract, DenseMSF).
  * ``msf_mpc_boruvka`` — the paper's MPC baseline (red/blue Borůvka,
    3 shuffles per phase, O(log n) phases).

All functions return a boolean mask over the *original* edge ids.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.coo import UGraph
from .rounds import RoundLedger, nbytes_of
from .ternarize import ternarize

INF = jnp.float32(jnp.inf)


# --------------------------------------------------------------------------
# Algorithm 1: truncated Prim
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("budget",))
def truncated_prim(nbr, nbw, nbe, rank, budget: int):
    """Run rank-truncated Prim from every vertex of a Δ<=3 graph.

    nbr/nbw/nbe: (n, D) padded adjacency (ids / weights / edge ids), -1 / inf pad.
    rank: (n,) distinct float ranks (the random permutation π).
    Returns (out_eids (n, budget), hooks (n,), cases (n,), queries (n,)).
    cases: 1 = budget hit, 2 = component exhausted, 3 = lower-rank hook.
    """
    n, D = nbr.shape
    F = D * budget  # frontier capacity

    def per_vertex(v):
        visited = jnp.full((budget,), -1, jnp.int32).at[0].set(v)
        fdst = jnp.full((F,), -1, jnp.int32).at[:D].set(nbr[v])
        fw = jnp.full((F,), INF).at[:D].set(nbw[v])
        feid = jnp.full((F,), -1, jnp.int32).at[:D].set(nbe[v])
        out = jnp.full((budget,), -1, jnp.int32)
        st = dict(visited=visited, vcount=jnp.int32(1), fdst=fdst, fw=fw,
                  feid=feid, fsize=jnp.int32(D), out=out, ocount=jnp.int32(0),
                  hook=jnp.int32(-1), case=jnp.int32(0), queries=jnp.int32(1))

        def cond(s):
            return s["case"] == 0

        def body(s):
            idx = jnp.argmin(s["fw"])
            best_w = s["fw"][idx]
            dst = s["fdst"][idx]
            eid = s["feid"][idx]
            exhausted = jnp.isinf(best_w)
            # consume the frontier entry
            fw = s["fw"].at[idx].set(INF)
            fdst = s["fdst"].at[idx].set(-1)
            already = (s["visited"] == dst).any()
            lower = rank[jnp.clip(dst, 0, n - 1)] < rank[v]
            room = s["vcount"] < budget

            def on_exhausted(s):
                return {**s, "case": jnp.int32(2), "fw": fw, "fdst": fdst}

            def on_seen(s):
                return {**s, "fw": fw, "fdst": fdst}

            def on_hook(s):
                out = s["out"].at[s["ocount"]].set(eid)
                return {**s, "fw": fw, "fdst": fdst, "out": out,
                        "ocount": s["ocount"] + 1, "hook": dst,
                        "case": jnp.int32(3), "queries": s["queries"] + 1}

            def on_add(s):
                visited = s["visited"].at[s["vcount"]].set(dst)
                out = s["out"].at[s["ocount"]].set(eid)
                pos = s["fsize"]
                fdst2 = jax.lax.dynamic_update_slice(fdst, nbr[dst], (pos,))
                fw2 = jax.lax.dynamic_update_slice(fw, nbw[dst], (pos,))
                feid2 = jax.lax.dynamic_update_slice(s["feid"], nbe[dst], (pos,))
                vcount = s["vcount"] + 1
                case = jnp.where(vcount >= budget, jnp.int32(1), jnp.int32(0))
                return {**s, "visited": visited, "vcount": vcount,
                        "fdst": fdst2, "fw": fw2, "feid": feid2,
                        "fsize": pos + D, "out": out, "ocount": s["ocount"] + 1,
                        "case": case, "queries": s["queries"] + 1}

            branch = jnp.where(exhausted, 0,
                               jnp.where(already, 1, jnp.where(lower, 2, 3)))
            return jax.lax.switch(branch, [on_exhausted, on_seen, on_hook, on_add], s)

        s = jax.lax.while_loop(cond, body, st)
        return s["out"], s["hook"], s["case"], s["queries"]

    return jax.vmap(per_vertex)(jnp.arange(n, dtype=jnp.int32))


# --------------------------------------------------------------------------
# Proposition 3.2: forest contraction by pointer jumping (in-round)
# --------------------------------------------------------------------------
@jax.jit
def pointer_jump(parent: jnp.ndarray):
    """Iterated doubling to the root; returns (roots, num_doublings)."""
    def cond(s):
        p, _ = s
        return jnp.any(p[p] != p)

    def body(s):
        p, it = s
        return p[p], it + 1

    p, iters = jax.lax.while_loop(cond, body, (parent, jnp.int32(0)))
    return p, iters


# --------------------------------------------------------------------------
# Contraction: relabel edges, drop self-loops, dedup (min weight per pair)
# --------------------------------------------------------------------------
@jax.jit
def contract_edges(u, v, w, eid, valid, labels):
    """Relabel endpoints by ``labels``; self-loops invalidated; duplicate
    (cu, cv) pairs keep only the minimum-weight edge. Shapes are static; a
    boolean ``valid`` mask tracks liveness.  Returns (cu, cv, w, eid, valid,
    n_live_vertices)."""
    cu = labels[u]
    cv = labels[v]
    lo = jnp.minimum(cu, cv)
    hi = jnp.maximum(cu, cv)
    valid = valid & (lo != hi)
    big = jnp.int32(jnp.iinfo(jnp.int32).max)
    klo = jnp.where(valid, lo, big)
    khi = jnp.where(valid, hi, big)
    order = jnp.lexsort((w, khi, klo))
    slo, shi = klo[order], khi[order]
    first = jnp.concatenate([jnp.ones((1,), bool),
                             (slo[1:] != slo[:-1]) | (shi[1:] != shi[:-1])])
    keep = jnp.zeros_like(valid).at[order].set(first) & valid
    # live vertex count: labels that appear as an endpoint of a live edge
    live = jnp.zeros(labels.shape[0], jnp.int32)
    live = live.at[jnp.where(keep, lo, 0)].max(keep.astype(jnp.int32), mode="drop")
    live = live.at[jnp.where(keep, hi, 0)].max(keep.astype(jnp.int32), mode="drop")
    return cu, cv, w, eid, keep, live.sum()


# --------------------------------------------------------------------------
# DenseMSF stand-in: in-round Borůvka (min-edge hooking + doubling)
# --------------------------------------------------------------------------
def _component_min_edge(lu, lv, w, eid, valid, n):
    """For each component label, the (weight, lane)-lexicographic minimum
    incident cross edge.  Lanes (edge positions) are unique even when edge
    ids repeat (ternarization dummy edges all carry eid=-1), so the choice is
    unambiguous and two components hooking each other always agree on the
    same edge.  Returns (min_eid (n,), partner (n,), has (n,))."""
    E = w.shape[0]
    cross = valid & (lu != lv)
    wbig = jnp.where(cross, w, INF)
    both_l = jnp.concatenate([lu, lv])
    seg_w = jax.ops.segment_min(jnp.concatenate([wbig, wbig]), both_l,
                                num_segments=n)
    lane = jnp.arange(E, dtype=jnp.int32)
    big = jnp.int32(2**30)
    lane_u = jnp.where(cross & (w <= seg_w[lu]), lane, big)
    lane_v = jnp.where(cross & (w <= seg_w[lv]), lane, big)
    seg_lane = jax.ops.segment_min(jnp.concatenate([lane_u, lane_v]), both_l,
                                   num_segments=n)
    has = seg_lane < big
    sl = jnp.clip(seg_lane, 0, E - 1)
    min_eid = jnp.where(has, eid[sl], -1)
    comp = jnp.arange(n, dtype=jnp.int32)
    plu, plv = lu[sl], lv[sl]
    partner = jnp.where(plu == comp, plv, plu)
    partner = jnp.where(has, partner, comp)
    return min_eid, partner, has


def boruvka_core(u, v, w, eid, valid, n_labels: int, max_eid: int):
    """Borůvka run to completion inside one program (while_loop).
    Traceable core — call inside other jitted programs; use
    ``boruvka_inround`` for a standalone launch.

    Returns (msf_mask over [0, max_eid), labels, phases)."""
    n = n_labels
    labels0 = jnp.arange(n, dtype=jnp.int32)
    mask0 = jnp.zeros((max_eid,), bool)

    def cond(s):
        labels, mask, it, done = s
        return ~done

    def body(s):
        labels, mask, it, _ = s
        lu, lv = labels[u], labels[v]
        min_eid, partner, has = _component_min_edge(lu, lv, w, eid, valid, n)
        parent = jnp.where(has, partner, labels0)
        # break 2-cycles: keep the hook only on the smaller label
        two = (parent[parent] == labels0) & (parent != labels0)
        parent = jnp.where(two & (labels0 > parent), labels0, parent)
        roots, _ = pointer_jump(parent)
        # an edge is selected if it was some component's min edge; invalid
        # lanes (no edge / dummy eid=-1) scatter out-of-bounds and are dropped
        sel = jnp.where(has & (min_eid >= 0), min_eid, max_eid)
        selected_mask = jnp.zeros((max_eid,), bool).at[sel].set(True, mode="drop")
        mask = mask | selected_mask
        labels = roots[labels]
        done = ~jnp.any(has)
        return labels, mask, it + 1, done

    labels, mask, phases, _ = jax.lax.while_loop(
        cond, body, (labels0, mask0, jnp.int32(0), jnp.asarray(False)))
    return mask, labels, phases


boruvka_inround = functools.partial(jax.jit, static_argnames=("n_labels", "max_eid"))(
    boruvka_core)


# --------------------------------------------------------------------------
# Algorithm 2 driver (AMPC): 5 materialized shuffles, like the paper's impl
# --------------------------------------------------------------------------
def msf_ampc(g: UGraph, epsilon: float = 0.5, seed: int = 0,
             ledger: Optional[RoundLedger] = None,
             skip_ternarize_if_dense: bool = True) -> Tuple[np.ndarray, dict]:
    """Compute the MSF mask over g.edges.  Returns (mask, stats)."""
    ledger = ledger if ledger is not None else RoundLedger("ampc_msf")
    assert g.weights is not None
    n, m = g.n, g.m
    rng = np.random.default_rng(seed)

    dense = skip_ternarize_if_dense and m >= n ** (1.0 + epsilon / 2.0)
    if dense:
        # Proposition 3.1 path: run the dense routine directly.
        u = jnp.asarray(g.edges[:, 0]); v = jnp.asarray(g.edges[:, 1])
        w = jnp.asarray(g.weights); eid = jnp.arange(m, dtype=jnp.int32)
        valid = jnp.ones((m,), bool)
        with ledger.shuffle("DenseMSF", nbytes_of(g.edges, g.weights)):
            mask, _, phases = boruvka_inround(u, v, w, eid, valid, n, m)
            mask = np.asarray(jax.device_get(mask))
        return mask, {"phases": int(jax.device_get(phases)), "path": "dense"}

    # --- shuffle 1: SortGraph (ternarize + build sorted adjacency, write DHT)
    with ledger.shuffle("SortGraph", nbytes_of(g.edges, g.weights)):
        tg = ternarize(g)
        nbr, nbw, nbe = tg.g.padded_adj(3)
        nt = tg.g.n
        rank = rng.permutation(nt).astype(np.float32)
        budget = max(2, int(np.ceil(nt ** (epsilon / 2.0))))
    ledger.record_queries(0, 0, waves=0)

    # --- shuffle 2: PrimSearch (adaptive queries against the DHT snapshot)
    jn_nbr, jn_nbw, jn_nbe = jnp.asarray(nbr), jnp.asarray(nbw), jnp.asarray(nbe)
    jn_rank = jnp.asarray(rank)
    with ledger.shuffle("PrimSearch", 0):
        out_eids, hooks, cases, queries = truncated_prim(
            jn_nbr, jn_nbw, jn_nbe, jn_rank, budget)
        total_q = int(jax.device_get(queries.sum()))
    row_bytes = 3 * (4 + 4 + 4)
    ledger.record_queries(total_q, total_q * row_bytes, waves=1)

    # --- shuffle 3: PointerJump (contract the hook forest, Prop 3.2)
    with ledger.shuffle("PointerJump", nbytes_of(np.asarray(hooks))):
        parent = jnp.where(hooks >= 0, hooks, jnp.arange(nt, dtype=jnp.int32))
        roots, jump_iters = pointer_jump(parent)
    ledger.record_queries(int(jax.device_get(jump_iters)) * nt,
                          int(jax.device_get(jump_iters)) * nt * 4, waves=1)

    # --- shuffle 4: Contract (relabel + dedup on the ternarized edge list)
    tu = jnp.asarray(tg.g.edges[:, 0]); tv = jnp.asarray(tg.g.edges[:, 1])
    tw = jnp.asarray(tg.g.weights); teid = jnp.asarray(tg.orig_eid)
    with ledger.shuffle("Contract", nbytes_of(tg.g.edges, tg.g.weights)):
        cu, cv, cw, ceid, cvalid, live = contract_edges(
            tu, tv, tw, teid, jnp.ones((tg.g.m,), bool), roots)
        live_v = int(jax.device_get(live))

    # --- shuffle 5: DenseMSF on the contracted graph
    with ledger.shuffle("DenseMSF", 0):
        dmask, dlabels, phases = boruvka_inround(cu, cv, cw, ceid, cvalid, nt, max(m, 1))
        dmask = np.asarray(jax.device_get(dmask))

    # union of Prim-discovered edges and the dense-phase edges
    prim_eids = np.asarray(jax.device_get(out_eids)).ravel()
    prim_eids = prim_eids[prim_eids >= 0]
    orig = tg.orig_eid[prim_eids]
    orig = orig[orig >= 0]
    mask = dmask.copy()
    if m:
        mask[orig] = True
    stats = {
        "path": "sparse",
        "budget": budget,
        "n_tern": nt,
        "queries": total_q,
        "avg_queries_per_vertex": total_q / max(nt, 1),
        "pointer_jump_iters": int(jax.device_get(jump_iters)),
        "contracted_vertices": live_v,
        "shrink_factor": nt / max(live_v, 1),
        "dense_phases": int(jax.device_get(phases)),
        "stop_cases": {int(k): int(c) for k, c in zip(
            *np.unique(np.asarray(jax.device_get(cases)), return_counts=True))},
    }
    return mask, stats


# --------------------------------------------------------------------------
# MPC baseline: red/blue Borůvka, 3 shuffles per phase (paper Section 5.5)
# --------------------------------------------------------------------------
@jax.jit
def _mpc_boruvka_phase(u, v, w, eid, valid, labels, color, max_eid_mask):
    """One red/blue Borůvka phase (paper Section 5.5): each *blue* component
    computes its overall minimum incident cross edge and contracts into the
    partner only if the partner is *red*."""
    n = labels.shape[0]
    lu, lv = labels[u], labels[v]
    min_eid, partner, has = _component_min_edge(lu, lv, w, eid, valid, n)
    ids = jnp.arange(n, dtype=jnp.int32)
    hook = has & color[ids] & ~color[partner]        # I am blue, partner red
    parent = jnp.where(hook, partner, ids)           # depth 1, acyclic
    sel = jnp.where(hook & (min_eid >= 0), min_eid, max_eid_mask.shape[0])
    selected = jnp.zeros_like(max_eid_mask).at[sel].set(True, mode="drop")
    labels = parent[labels]
    new_valid = valid & (labels[u] != labels[v])
    remaining = new_valid.sum()
    return labels, selected, new_valid, remaining


def msf_mpc_boruvka(g: UGraph, seed: int = 0,
                    ledger: Optional[RoundLedger] = None,
                    max_phases: int = 200) -> Tuple[np.ndarray, dict]:
    ledger = ledger if ledger is not None else RoundLedger("mpc_msf")
    n, m = g.n, g.m
    rng = np.random.default_rng(seed)
    u = jnp.asarray(g.edges[:, 0]); v = jnp.asarray(g.edges[:, 1])
    w = jnp.asarray(g.weights); eid = jnp.arange(m, dtype=jnp.int32)
    valid = jnp.ones((m,), bool)
    labels = jnp.arange(n, dtype=jnp.int32)
    mask = np.zeros(m, bool)
    phase_bytes = nbytes_of(g.edges, g.weights)
    phases = 0
    remaining = m
    while remaining > 0 and phases < max_phases:
        color = jnp.asarray(rng.random(n) < 0.5)
        # the paper's MPC algorithm performs 3 shuffles per contraction phase
        with ledger.shuffle(f"boruvka_minedge_{phases}", phase_bytes):
            pass
        with ledger.shuffle(f"boruvka_hook_{phases}", n * 4):
            labels, selected, valid, rem = _mpc_boruvka_phase(
                u, v, w, eid, valid, labels, color,
                jnp.zeros((m,), bool))
        with ledger.shuffle(f"boruvka_relabel_{phases}", phase_bytes):
            mask |= np.asarray(jax.device_get(selected))
            remaining = int(jax.device_get(rem))
        phases += 1
    return mask, {"phases": phases}
