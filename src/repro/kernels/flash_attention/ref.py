"""Pure-jnp oracle for flash attention (GQA, causal, optional window)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = jnp.float32(-1e30)


def attention_ref(q, k, v, causal: bool = True, window: int = 0,
                  softmax_scale=None):
    """q: (B, S, H, D); k/v: (B, K, Hkv, D). window<=0 => unbounded."""
    B, S, H, D = q.shape
    K, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / np.sqrt(D)
    qf = q.astype(jnp.float32).reshape(B, S, Hkv, G, D)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32)) * scale
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(K)[None, :]
    diff = (qpos + (K - S)) - kpos   # align last q with last k
    mask = jnp.ones((S, K), bool)
    if causal:
        mask &= diff >= 0
    if window and window > 0:
        mask &= diff < window
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, S, H, D).astype(q.dtype)
