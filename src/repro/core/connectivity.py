"""Connected components in O(1) adaptive rounds (paper Theorem 1).

The paper obtains connectivity from MSF: compute any spanning forest, then
apply forest connectivity (Proposition 3.2).  ``cc_ampc`` runs the same
5-shuffle pipeline as ``msf_ampc`` on unit weights (edge-id tie-broken) and
composes the two contraction maps into per-vertex component labels.

``cc_mpc_hash_to_min`` is the MPC baseline: min-label propagation with one
materialized launch per phase (the CC-LocalContraction stand-in used for the
1-vs-2-cycle comparison in Section 5.6).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.coo import UGraph
from .rounds import RoundLedger, nbytes_of
from .ternarize import ternarize
from .msf import (truncated_prim, pointer_jump, contract_edges,
                  boruvka_inround)


def _canonicalize(labels: np.ndarray) -> np.ndarray:
    """Relabel components by their minimum vertex id (oracle convention).
    Label values may live in any id space (e.g. ternarized vertices)."""
    n = labels.shape[0]
    _, inv = np.unique(labels, return_inverse=True)
    rep = np.full(inv.max() + 1, n, np.int64)
    np.minimum.at(rep, inv, np.arange(n))
    return rep[inv]


def cc_ampc(g: UGraph, epsilon: float = 0.5, seed: int = 0,
            ledger: Optional[RoundLedger] = None) -> Tuple[np.ndarray, dict]:
    """Connected components; returns (labels(n,) canonical, stats)."""
    ledger = ledger if ledger is not None else RoundLedger("ampc_cc")
    n, m = g.n, g.m
    if m == 0:
        return np.arange(n, dtype=np.int64), {"queries": 0}
    gw = UGraph(n, g.edges, np.arange(m, dtype=np.float32))  # unit-ish distinct
    rng = np.random.default_rng(seed)

    with ledger.shuffle("SortGraph", nbytes_of(gw.edges)):
        tg = ternarize(gw)
        nbr, nbw, nbe = tg.g.padded_adj(3)
        nt = tg.g.n
        rank = rng.permutation(nt).astype(np.float32)
        budget = max(2, int(np.ceil(nt ** (epsilon / 2.0))))
        # first tern slot of each original vertex (node_of is sorted)
        first_slot = np.searchsorted(tg.node_of, np.arange(n))

    with ledger.shuffle("PrimSearch", 0):
        out_eids, hooks, cases, queries = truncated_prim(
            jnp.asarray(nbr), jnp.asarray(nbw), jnp.asarray(nbe),
            jnp.asarray(rank), budget)
        total_q = int(jax.device_get(queries.sum()))
    ledger.record_queries(total_q, total_q * 36, waves=1)

    with ledger.shuffle("PointerJump", nbytes_of(np.asarray(hooks))):
        parent = jnp.where(hooks >= 0, hooks, jnp.arange(nt, dtype=jnp.int32))
        roots, jump_iters = pointer_jump(parent)

    tu = jnp.asarray(tg.g.edges[:, 0]); tv = jnp.asarray(tg.g.edges[:, 1])
    tw = jnp.asarray(tg.g.weights); teid = jnp.asarray(tg.orig_eid)
    with ledger.shuffle("Contract", nbytes_of(tg.g.edges)):
        cu, cv, cw, ceid, cvalid, live = contract_edges(
            tu, tv, tw, teid, jnp.ones((tg.g.m,), bool), roots)

    with ledger.shuffle("ForestConnectivity", 0):
        _, dlabels, phases = boruvka_inround(cu, cv, cw, ceid, cvalid, nt,
                                             max(m, 1))
        final_tern = jnp.take(dlabels, roots)          # compose contractions
        orig_labels = jnp.take(final_tern, jnp.asarray(first_slot))
        orig_labels = np.asarray(jax.device_get(orig_labels)).astype(np.int64)

    labels = _canonicalize(orig_labels)
    stats = {
        "queries": total_q,
        "pointer_jump_iters": int(jax.device_get(jump_iters)),
        "dense_phases": int(jax.device_get(phases)),
        "num_components": int(len(np.unique(labels))),
    }
    return labels, stats


# --------------------------------------------------------------------------
# MPC baseline: min-label propagation (hash-to-min), one launch per phase
# --------------------------------------------------------------------------
@jax.jit
def _h2m_phase(u, v, labels):
    lu, lv = labels[u], labels[v]
    mn = jnp.minimum(lu, lv)
    n = labels.shape[0]
    new = labels
    new = new.at[u].min(mn)
    new = new.at[v].min(mn)
    new = new.at[lu].min(mn)   # hash-to-min: also hook the current root
    new = new.at[lv].min(mn)
    new = jnp.take(new, new)   # shortcut
    changed = jnp.any(new != labels)
    return new, changed


def cc_mpc_hash_to_min(g: UGraph, ledger: Optional[RoundLedger] = None,
                       max_phases: int = 200) -> Tuple[np.ndarray, dict]:
    ledger = ledger if ledger is not None else RoundLedger("mpc_cc")
    n = g.n
    u = jnp.asarray(g.edges[:, 0]); v = jnp.asarray(g.edges[:, 1])
    labels = jnp.arange(n, dtype=jnp.int32)
    phases = 0
    nb = nbytes_of(g.edges)
    while phases < max_phases:
        with ledger.shuffle(f"h2m_join_{phases}", nb):
            labels, changed = _h2m_phase(u, v, labels)
        with ledger.shuffle(f"h2m_update_{phases}", n * 4):
            ch = bool(jax.device_get(changed))
        phases += 1
        if not ch:
            break
    labels = _canonicalize(np.asarray(jax.device_get(labels)).astype(np.int64))
    return labels, {"phases": phases,
                    "num_components": int(len(np.unique(labels)))}
