"""Corollary 4.1 applications: weighted matching + vertex cover."""
import itertools

import numpy as np
import pytest

from repro.graph import generators as gen
from repro.graph.coo import UGraph
from repro.core import oracle
from repro.core.weighted_matching import mwm_greedy_ampc, vertex_cover_2approx


def _brute_max_weight_matching(g):
    best = 0.0
    edges = g.edges.tolist()
    for k in range(min(len(edges), g.n // 2), 0, -1):
        for combo in itertools.combinations(range(len(edges)), k):
            used = set()
            ok = True
            w = 0.0
            for ei in combo:
                u, v = edges[ei]
                if u in used or v in used:
                    ok = False
                    break
                used.add(u); used.add(v)
                w += float(g.weights[ei])
            if ok:
                best = max(best, w)
    return best


def test_mwm_matches_sequential_greedy():
    g = gen.rmat(8, 6.0, seed=1).with_random_weights(3)
    got, st = mwm_greedy_ampc(g, seed=0)
    want = oracle.greedy_mm(g, st["erank"])
    assert np.array_equal(got, want)
    assert oracle.is_maximal_matching(g, got)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_mwm_half_approximation(seed):
    rng = np.random.default_rng(seed)
    e = rng.integers(0, 10, (14, 2)).astype(np.int32)
    g = UGraph(10, e).dedup()
    if g.m == 0:
        return
    g = UGraph(g.n, g.edges, rng.random(g.m).astype(np.float32) + 0.1)
    got, st = mwm_greedy_ampc(g, seed=seed)
    opt = _brute_max_weight_matching(g)
    assert st["weight"] * 2 + 1e-5 >= opt


def test_vertex_cover_covers_and_2approx():
    g = gen.erdos_renyi(60, 4.0, seed=2)
    cover, st = vertex_cover_2approx(g, seed=0)
    for u, v in g.edges:
        assert cover[u] or cover[v]
    # |cover| = 2|MM| and any VC >= |MM|  =>  2-approx by construction
    assert st["cover_size"] % 2 == 0
