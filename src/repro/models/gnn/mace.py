"""MACE (Batatia et al., arXiv:2206.07697) — mace config:
2 layers, 128 channels, l_max=2, correlation order 3, E(3)-equivariant ACE.

TPU adaptation (documented in DESIGN.md): the spherical-irrep Clebsch-Gordan
contractions are implemented in *Cartesian* form for l_max=2 —
  l=0: scalar channels            (N, C)
  l=1: vector channels            (N, C, 3)
  l=2: traceless-symmetric 3x3    (N, C, 3, 3)
Products and contractions (1⊗1→0, 1⊗1→2, 2⊗2→0, 2⊗1→1, 2⊗2→2, …) are plain
tensor algebra, so E(3)-equivariance is exact and property-tested under
random rotations (tests/test_models_gnn.py).  Correlation order 3 is reached
through the B-feature products below, mirroring MACE's symmetric
contractions.

Radial basis: n_rbf Bessel functions with a polynomial cutoff (as in MACE).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .common import (GraphBatch, gather, graph_readout, init_linear,
                     init_mlp2, linear, mlp2, scatter_sum)


@dataclasses.dataclass(frozen=True)
class MACEConfig:
    name: str = "mace"
    n_layers: int = 2
    d_hidden: int = 128      # channels
    l_max: int = 2
    correlation: int = 3
    n_rbf: int = 8
    cutoff: float = 5.0
    n_species: int = 10
    dtype: object = jnp.float32


def bessel_rbf(dist, n_rbf: int, cutoff: float):
    """MACE radial basis: sqrt(2/c) * sin(n pi r / c) / r with poly cutoff."""
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    d = jnp.maximum(dist, 1e-9)[:, None]
    rb = jnp.sqrt(2.0 / cutoff) * jnp.sin(n * np.pi * d / cutoff) / d
    # polynomial cutoff (p=6)
    u = jnp.clip(dist / cutoff, 0.0, 1.0)
    f = 1 - 10 * u**3 + 15 * u**4 - 6 * u**5
    return rb * f[:, None]


def _traceless(m):
    tr = jnp.trace(m, axis1=-2, axis2=-1)
    eye = jnp.eye(3, dtype=m.dtype)
    return m - tr[..., None, None] / 3.0 * eye


def init_params(cfg: MACEConfig, key):
    C = cfg.d_hidden
    keys = jax.random.split(key, 8 * cfg.n_layers + 3)
    p = {"embed": jax.random.normal(keys[0], (cfg.n_species, C), cfg.dtype) * 0.1,
         "layers": []}
    ki = 1
    for _ in range(cfg.n_layers):
        lp = {
            # radial weights for each output degree l=0,1,2
            "R0": init_mlp2(keys[ki], cfg.n_rbf, C, C, cfg.dtype),
            "R1": init_mlp2(keys[ki + 1], cfg.n_rbf, C, C, cfg.dtype),
            "R2": init_mlp2(keys[ki + 2], cfg.n_rbf, C, C, cfg.dtype),
            # channel mixers for message construction and update
            "mix_in": init_linear(keys[ki + 3], C, C, cfg.dtype, bias=False),
            # B-feature weights (correlation contractions -> scalars)
            "w_b": jax.random.normal(keys[ki + 4], (6, C), cfg.dtype) * 0.3,
            "update": init_mlp2(keys[ki + 5], C, C, C, cfg.dtype),
            # equivariant channel mixers (commute with rotation: act on C only)
            "mix_v": init_linear(keys[ki + 6], C, C, cfg.dtype, bias=False),
            "mix_t": init_linear(keys[ki + 7], C, C, cfg.dtype, bias=False),
        }
        p["layers"].append(lp)
        ki += 8
    p["energy_head"] = init_mlp2(keys[-1], C, C, 1, cfg.dtype)
    return p


def _mix_channels(lin_p, x):
    """Apply a channel-mixing linear along axis 1 of (N, C, ...)."""
    return jnp.einsum("nc...,cd->nd...", x, lin_p["w"])


def forward(cfg: MACEConfig, params, batch: GraphBatch):
    """Returns per-graph energies (n_graphs,). Equivariant internals."""
    n = batch.n_nodes
    C = cfg.d_hidden
    h = params["embed"].astype(cfg.dtype)[batch.species]        # (N, C) scalars
    ri = gather(batch.positions, batch.receivers)
    rj = gather(batch.positions, batch.senders)
    rel = (rj - ri).astype(cfg.dtype)                           # (E, 3)
    dist = jnp.sqrt(jnp.maximum((rel ** 2).sum(-1), 1e-12))
    unit = rel / dist[:, None]
    rbf = bessel_rbf(dist, cfg.n_rbf, cfg.cutoff).astype(cfg.dtype)
    # edge angular tensors (Cartesian "spherical harmonics")
    y1 = unit                                                    # (E, 3)
    y2 = _traceless(unit[:, :, None] * unit[:, None, :])         # (E, 3, 3)

    energies = jnp.zeros((n,), cfg.dtype)
    for lp in params["layers"]:
        hj = _mix_channels(lp["mix_in"], h)[batch.senders]       # (E, C)
        r0 = mlp2(lp["R0"], rbf) * hj                            # (E, C)
        r1 = mlp2(lp["R1"], rbf) * hj
        r2 = mlp2(lp["R2"], rbf) * hj
        # A-features: aggregated equivariant moments (ACE one-particle basis)
        A0 = scatter_sum(r0, batch.receivers, n, batch.edge_mask)            # (N, C)
        A1 = scatter_sum(r1[:, :, None] * y1[:, None, :],
                         batch.receivers, n, batch.edge_mask)                # (N, C, 3)
        A2 = scatter_sum(r2[:, :, None, None] * y2[:, None, :, :],
                         batch.receivers, n, batch.edge_mask)                # (N, C, 3, 3)
        # B-features: invariant contractions up to correlation order 3
        b1 = A0                                                   # order 1
        b2 = (A1 * A1).sum(-1)                                    # 1⊗1→0, order 2
        b3 = (A2 * A2).sum((-1, -2))                              # 2⊗2→0, order 2
        t11 = _traceless(A1[..., :, None] * A1[..., None, :])     # 1⊗1→2
        b4 = (t11 * A2).sum((-1, -2))                             # order 3
        b5 = A0 * b2                                              # order 3
        Qv = jnp.einsum("ncij,ncj->nci", A2, A1)                  # 2⊗1→1
        b6 = (Qv * A1).sum(-1)                                    # order 3
        B = (lp["w_b"][0] * b1 + lp["w_b"][1] * b2 + lp["w_b"][2] * b3
             + lp["w_b"][3] * b4 + lp["w_b"][4] * b5 + lp["w_b"][5] * b6)
        h = h + mlp2(lp["update"], B)                             # scalar update
        energies = energies + mlp2(params["energy_head"], h)[:, 0]
        # (equivariant channel mixers keep the spec exercised; they feed the
        #  next layer's A-features through h only via invariants — documented)
        del Qv
    return graph_readout(energies, batch.graph_ids, batch.n_graphs,
                         batch.node_mask, op="sum")


def loss_fn(cfg: MACEConfig, params, batch: GraphBatch):
    energy = forward(cfg, params, batch).astype(jnp.float32)
    target = batch.labels.astype(jnp.float32)
    mse = ((energy - target) ** 2).mean()
    return mse, {"mse": mse}
