"""Numpy-based sharded checkpointer: atomic, resumable, mesh-elastic.

Layout: <dir>/step_<N>/ with one .npy per leaf + manifest.json.  Writes go to
a ``.tmp`` directory first and are atomically renamed — a preempted writer
never corrupts the latest checkpoint (the fault-tolerance property the paper
gets from Flume's durable shuffles).  ``restore`` can re-shard onto a
different mesh (elastic restart): leaves are loaded on host and
``device_put`` with the *target* shardings.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Optional

import jax
import numpy as np


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = []
    for path, leaf in flat:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        names.append("__".join(parts) or "leaf")
    return names, [l for _, l in flat], treedef


def save(ckpt_dir: str, step: int, tree: Any, keep: int = 3) -> str:
    names, leaves, _ = _leaf_paths(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": []}
    for name, leaf in zip(names, leaves):
        arr = np.asarray(jax.device_get(leaf))
        fn = f"{len(manifest['leaves']):05d}_{name[:80]}.npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"].append({"name": name, "file": fn,
                                   "dtype": str(arr.dtype),
                                   "shape": list(arr.shape)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)          # atomic commit
    _cleanup(ckpt_dir, keep)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for d in os.listdir(ckpt_dir)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, tree_like: Any, step: Optional[int] = None,
            shardings: Any = None) -> tuple:
    """Returns (tree, step). ``tree_like`` provides the pytree structure;
    ``shardings`` (optional, congruent pytree) re-shards onto the current
    mesh — a checkpoint written on one mesh restores onto any other."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    names, leaves, treedef = _leaf_paths(tree_like)
    by_name = {l["name"]: l for l in manifest["leaves"]}
    out = []
    sh_flat = (jax.tree_util.tree_leaves(shardings)
               if shardings is not None else [None] * len(leaves))
    for name, like, sh in zip(names, leaves, sh_flat):
        rec = by_name[name]
        arr = np.load(os.path.join(d, rec["file"]))
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), step


def _cleanup(ckpt_dir: str, keep: int):
    steps = sorted([int(m.group(1)) for d in os.listdir(ckpt_dir)
                    if (m := re.fullmatch(r"step_(\d+)", d))])
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
