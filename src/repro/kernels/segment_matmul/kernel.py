"""Pallas TPU kernel: fused neighbor-gather + sum + matmul (padded-CSR SpMM).

Grid over node blocks; the neighbor-id block is scalar-prefetched to SMEM
(PrefetchScalarGridSpec) so row DMAs from the HBM-resident feature table can
be issued with data-dependent indices — the same adaptive-lookup pattern as
the AMPC DHT.  The accumulated block then hits the MXU once for the weight
transform.

VMEM working set per step: (bn, D) accumulator + (D, F) weight tile + the
row buffer — bn=8, D,F <= 512 keeps it well under 16 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _seg_mm_kernel(nbr_ref, x_ref, w_ref, o_ref, acc_ref, *, bn: int, K: int):
    i = pl.program_id(0)

    acc_ref[...] = jnp.zeros_like(acc_ref)
    for r in range(bn):            # unrolled: bn is small (8)
        row_acc = jnp.zeros((1, x_ref.shape[1]), jnp.float32)
        for k in range(K):
            idx = nbr_ref[i * bn + r, k]
            valid = idx >= 0
            safe = jnp.maximum(idx, 0)
            row = pl.load(x_ref, (pl.ds(safe, 1), slice(None)))
            row_acc = row_acc + jnp.where(valid, row.astype(jnp.float32), 0.0)
        acc_ref[r, :] = row_acc[0]
    o_ref[...] = jax.lax.dot_general(
        acc_ref[...], w_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def segment_matmul_pallas(x, nbr, w, block_n: int = 8, interpret: bool = True):
    """x: (N, D); nbr: (N, K) int32 (-1 pad); w: (D, F) -> (N, F)."""
    N, D = x.shape
    K = nbr.shape[1]
    F = w.shape[1]
    bn = min(block_n, N)
    assert N % bn == 0
    grid = (N // bn,)
    kernel = functools.partial(_seg_mm_kernel, bn=bn, K=K)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.ANY),      # x stays in HBM
                pl.BlockSpec((D, F), lambda i, nbr: (0, 0)),
            ],
            out_specs=pl.BlockSpec((bn, F), lambda i, nbr: (i, 0)),
            scratch_shapes=[pltpu.VMEM((bn, D), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((N, F), x.dtype),
        interpret=interpret,
    )(nbr, x, w)
