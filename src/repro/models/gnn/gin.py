"""GIN (Xu et al., arXiv:1810.00826) — gin-tu config:
5 layers, d_hidden=64, sum aggregator, learnable eps, graph classification."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import (GraphBatch, gather, graph_readout, init_mlp2, mlp2,
                     scatter_sum, init_linear, linear)


@dataclasses.dataclass(frozen=True)
class GINConfig:
    name: str = "gin-tu"
    n_layers: int = 5
    d_feat: int = 64
    d_hidden: int = 64
    n_classes: int = 2
    dtype: object = jnp.float32


def init_params(cfg: GINConfig, key):
    keys = jax.random.split(key, cfg.n_layers + 1)
    layers = []
    d_in = cfg.d_feat
    for i in range(cfg.n_layers):
        layers.append({
            "mlp": init_mlp2(keys[i], d_in, cfg.d_hidden, cfg.d_hidden, cfg.dtype),
            "eps": jnp.zeros((), cfg.dtype),
        })
        d_in = cfg.d_hidden
    return {"layers": layers,
            "readout": init_linear(keys[-1], cfg.d_hidden, cfg.n_classes,
                                   cfg.dtype)}


def forward(cfg: GINConfig, params, batch: GraphBatch):
    n = batch.n_nodes
    x = batch.node_feat.astype(cfg.dtype)
    for layer in params["layers"]:
        msg = gather(x, batch.senders)
        agg = scatter_sum(msg, batch.receivers, n, batch.edge_mask)
        x = mlp2(layer["mlp"], (1.0 + layer["eps"]) * x + agg,
                 act=jax.nn.relu)
    pooled = graph_readout(x, batch.graph_ids, batch.n_graphs,
                           batch.node_mask, op="sum")
    return linear(params["readout"], pooled)  # (n_graphs, n_classes)


def loss_fn(cfg: GINConfig, params, batch: GraphBatch):
    logits = forward(cfg, params, batch).astype(jnp.float32)
    labels = batch.labels  # (n_graphs,)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    nll = (logz - gold).mean()
    return nll, {"nll": nll}
