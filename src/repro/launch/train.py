"""Training launcher (CLI): end-to-end LM training with checkpoint/restart.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \
      --steps 50 --ckpt-dir /tmp/run1

On CPU this trains the reduced (smoke) config; on a real TPU mesh the same
driver jits the same step with the production shardings (launch/specs.py).
Resume: re-running with the same --ckpt-dir continues from the latest step.
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp

from ..configs.registry import get
from ..data.tokens import TokenStreamConfig, batch_at_step
from ..models import transformer as tr
from ..optim import adamw
from ..checkpoint import checkpointer as ckpt
from . import steps


def train_lm(arch: str, smoke: bool, n_steps: int, ckpt_dir: str,
             batch: int = 8, seq_len: int = 64, ckpt_every: int = 20,
             log_every: int = 10, seed: int = 0):
    entry = get(arch)
    cfg = entry.smoke_config if smoke else entry.config
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=20,
                                total_steps=max(n_steps, 100))
    params = tr.init_params(cfg, jax.random.PRNGKey(seed))
    opt_state = adamw.init_state(params)
    state = {"params": params, "opt": opt_state}

    start = 0
    if ckpt_dir and ckpt.latest_step(ckpt_dir) is not None:
        state, last = ckpt.restore(ckpt_dir, state)
        start = last + 1
        print(f"resumed from step {last}")

    step_fn = jax.jit(functools.partial(steps.lm_train_step, cfg, opt_cfg))
    stream = TokenStreamConfig(vocab=cfg.vocab, seq_len=seq_len,
                               global_batch=batch, seed=seed)
    losses = []
    for step in range(start, n_steps):
        tokens, labels = batch_at_step(stream, step)
        p, o, metrics = step_fn(state["params"], state["opt"],
                                jnp.asarray(tokens), jnp.asarray(labels))
        state = {"params": p, "opt": o}
        losses.append(float(metrics["loss"]))
        if step % log_every == 0:
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            ckpt.save(ckpt_dir, step, state)
    if ckpt_dir:
        ckpt.save(ckpt_dir, n_steps - 1, state)
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()
    t0 = time.time()
    losses = train_lm(args.arch, args.smoke, args.steps, args.ckpt_dir,
                      batch=args.batch, seq_len=args.seq_len)
    print(f"done in {time.time()-t0:.1f}s  first={losses[0]:.3f} "
          f"last={losses[-1]:.3f}")


if __name__ == "__main__":
    main()
