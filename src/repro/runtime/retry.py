"""Resilient execution of jitted programs.

Two concerns are handled here:

1. **Runtime-level retry** (fault tolerance): a launch that fails with a
   transient runtime error is retried after invalidating the executable
   cache — the same recovery path a production runner takes after losing a
   worker mid-step (recompile + re-execute from the last materialized
   round).  This also works around an XLA-CPU executable re-execution bug
   observed in this environment ("Execution supplied N buffers but compiled
   program expected M buffers" on a warm-cache second execution), which we
   treat exactly like a lost executable.

2. **Bounded retries**: repeated failure surfaces the original error.

Every retry is observable, not just logged: it increments
``retry_transients_total{marker}`` on the process metrics registry
(:func:`repro.obs.metrics.default_registry`) and attaches a WARN-level
``transient_retry`` event to whatever span is currently open (the
enclosing solve / benchmark), so retries show up inline in exported
timelines.
"""
from __future__ import annotations

import contextlib
import logging
import threading
from typing import Any, Callable, List, Optional

import jax

from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace

log = logging.getLogger(__name__)

_TRANSIENT_MARKERS = (
    "buffers but compiled program expected",   # XLA CPU re-execution bug
    "RESOURCE_EXHAUSTED",
    "preempted",
)


def transient_marker(err: Exception) -> Optional[str]:
    """The first transient marker matching ``err``, or None."""
    msg = str(err)
    for marker in _TRANSIENT_MARKERS:
        if marker in msg:
            return marker
    return None


def is_transient(err: Exception) -> bool:
    return transient_marker(err) is not None


def _observe_retry(marker: str, attempt: int, retries: int,
                   err: Exception) -> None:
    obs_metrics.default_registry().counter(
        "retry_transients_total", labelnames=("marker",)).inc(1,
                                                              marker=marker)
    obs_trace.current_tracer().event(
        "transient_retry", level="WARN", marker=marker, attempt=attempt,
        retries=retries, error=str(err)[:200])


class _FaultPlan:
    """One armed injection: fail the next ``times`` resilient calls."""

    def __init__(self, marker: str, times: int):
        self.marker = marker
        self.times = times


_fault_lock = threading.Lock()
_fault_plans: List[_FaultPlan] = []


@contextlib.contextmanager
def inject_transients(marker: str = "preempted", times: int = 1):
    """Test hook: make the next ``times`` :func:`resilient_call` attempts
    fail with a synthetic transient error carrying ``marker``.

    The failure is raised *inside* the protected call path, so it exercises
    the real recovery machinery — ``retry_transients_total`` increments, the
    WARN ``transient_retry`` event lands on the caller's open span, and with
    ``times > _retries`` the exhaustion path surfaces the injected error.
    Process-global (any thread's resilient call consumes the plan), so
    pooled async solves are injectable from the submitting thread.
    """
    if marker not in _TRANSIENT_MARKERS:
        raise ValueError(f"marker {marker!r} is not one of the transient "
                         f"markers {_TRANSIENT_MARKERS}")
    plan = _FaultPlan(marker, int(times))
    with _fault_lock:
        _fault_plans.append(plan)
    try:
        yield plan
    finally:
        with _fault_lock:
            if plan in _fault_plans:
                _fault_plans.remove(plan)


def _maybe_inject() -> None:
    with _fault_lock:
        for plan in _fault_plans:
            if plan.times > 0:
                plan.times -= 1
                raise ValueError(
                    f"injected transient failure ({plan.marker})")


def resilient_call(fn: Callable, *args, _retries: int = 2, **kwargs) -> Any:
    """Call ``fn`` (usually a jitted function); on a transient runtime
    failure, drop cached executables and retry (recompiles)."""
    attempt = 0
    while True:
        try:
            _maybe_inject()
            return fn(*args, **kwargs)
        except ValueError as e:  # jaxlib surfaces XLA runtime errors as ValueError
            marker = transient_marker(e)
            if attempt >= _retries or marker is None:
                raise
            attempt += 1
            _observe_retry(marker, attempt, _retries, e)
            log.warning("transient launch failure (%s); clearing caches and "
                        "retrying (%d/%d)", e, attempt, _retries)
            try:
                if hasattr(fn, "clear_cache"):
                    fn.clear_cache()
                else:
                    jax.clear_caches()
            except Exception:  # pragma: no cover - best effort
                jax.clear_caches()
