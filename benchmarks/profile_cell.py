"""Hillclimb profiler: lower one cell, attribute FLOPs/bytes/collectives.

  PYTHONPATH=src python -m benchmarks.profile_cell --arch qwen2.5-32b \
      --shape train_4k
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--save-hlo", default="")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (int/bool/str)")
    args = ap.parse_args()
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        if v in ("True", "False"):
            overrides[k] = v == "True"
        else:
            try:
                overrides[k] = int(v)
            except ValueError:
                overrides[k] = v

    from repro.launch.mesh import make_production_mesh, n_chips
    from repro.launch.specs import build_lowerable
    from repro.launch.hlo import analyze_hlo, roofline_terms

    mesh = make_production_mesh(multi_pod=args.multi)
    low = build_lowerable(args.arch, args.shape, mesh,
                          overrides=overrides or None)
    compiled = low.lower(mesh).compile()
    txt = compiled.as_text()
    if args.save_hlo:
        open(args.save_hlo, "w").write(txt)
    a = analyze_hlo(txt)
    terms = roofline_terms(a, n_chips(mesh), low.model_flops)
    print(json.dumps({k: v for k, v in terms.items()
                      if not isinstance(v, dict)}, indent=1, default=str))
    print("\n-- top byte ops (per-device bytes) --")
    for op, b in a.top_byte_ops():
        print(f"  {b:12.4g}  {op}")
    print("\n-- top collective sites (per-device wire bytes) --")
    for site, b in a.top_collective_sites():
        print(f"  {b:12.4g}  {site}")
    mem = compiled.memory_analysis()
    print(f"\nmemory: args={mem.argument_size_in_bytes/1e9:.2f}GB "
          f"temp={mem.temp_size_in_bytes/1e9:.2f}GB")


if __name__ == "__main__":
    main()
