"""SchNet (Schütt et al., arXiv:1706.08566) — schnet config:
3 interaction blocks, d_hidden=64, 300 gaussian RBFs, cutoff 10 Å.
Continuous-filter convolution: W(r_ij) ⊙ h_j aggregated per atom."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .common import (GraphBatch, gather, graph_readout, init_linear,
                     init_mlp2, linear, mlp2, scatter_sum)


@dataclasses.dataclass(frozen=True)
class SchNetConfig:
    name: str = "schnet"
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0
    n_species: int = 100
    dtype: object = jnp.float32


def shifted_softplus(x):
    return jax.nn.softplus(x) - np.log(2.0)


def rbf_expand(dist, n_rbf: int, cutoff: float):
    centers = jnp.linspace(0.0, cutoff, n_rbf)
    gamma = 10.0 / cutoff
    return jnp.exp(-gamma * (dist[:, None] - centers[None, :]) ** 2)


def init_params(cfg: SchNetConfig, key):
    keys = jax.random.split(key, 3 * cfg.n_interactions + 3)
    d = cfg.d_hidden
    p = {"embed": jax.random.normal(keys[0], (cfg.n_species, d), cfg.dtype) * 0.1,
         "interactions": []}
    for i in range(cfg.n_interactions):
        k1, k2, k3 = keys[1 + 3 * i:4 + 3 * i]
        p["interactions"].append({
            "filter": init_mlp2(k1, cfg.n_rbf, d, d, cfg.dtype),
            "in_lin": init_linear(k2, d, d, cfg.dtype, bias=False),
            "out": init_mlp2(k3, d, d, d, cfg.dtype),
        })
    p["energy_head"] = init_mlp2(keys[-1], d, d // 2, 1, cfg.dtype)
    return p


def forward(cfg: SchNetConfig, params, batch: GraphBatch):
    """Returns per-graph energies (n_graphs,)."""
    n = batch.n_nodes
    x = params["embed"].astype(cfg.dtype)[batch.species]
    ri = gather(batch.positions, batch.receivers)
    rj = gather(batch.positions, batch.senders)
    dist = jnp.sqrt(jnp.maximum(((ri - rj) ** 2).sum(-1), 1e-12))
    rbf = rbf_expand(dist, cfg.n_rbf, cfg.cutoff).astype(cfg.dtype)
    # smooth cutoff envelope
    env = 0.5 * (jnp.cos(np.pi * jnp.clip(dist / cfg.cutoff, 0, 1)) + 1.0)
    for blk in params["interactions"]:
        w = mlp2(blk["filter"], rbf, act=shifted_softplus) * env[:, None].astype(cfg.dtype)
        hj = gather(linear(blk["in_lin"], x), batch.senders)
        agg = scatter_sum(hj * w, batch.receivers, n, batch.edge_mask)
        x = x + mlp2(blk["out"], agg, act=shifted_softplus)
    atom_e = mlp2(params["energy_head"], x, act=shifted_softplus)[:, 0]
    return graph_readout(atom_e, batch.graph_ids, batch.n_graphs,
                         batch.node_mask, op="sum")


def loss_fn(cfg: SchNetConfig, params, batch: GraphBatch):
    energy = forward(cfg, params, batch).astype(jnp.float32)
    target = batch.labels.astype(jnp.float32)
    mse = ((energy - target) ** 2).mean()
    return mse, {"mse": mse}
