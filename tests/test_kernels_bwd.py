"""Flash attention backward kernel vs jax.grad of the jnp oracle."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention.bwd import flash_attention_trainable
from repro.kernels.flash_attention.ref import attention_ref


@pytest.mark.parametrize("B,S,K,H,Hkv,D,causal,window", [
    (1, 128, 128, 2, 2, 32, True, 0),
    (2, 128, 128, 4, 2, 32, True, 0),     # GQA
    (1, 128, 128, 2, 2, 32, True, 64),    # windowed
    (1, 128, 128, 2, 1, 64, False, 0),    # bidirectional, MQA
])
def test_flash_bwd_matches_ref_grads(B, S, K, H, Hkv, D, causal, window):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, S, H, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, K, Hkv, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, K, Hkv, D)).astype(np.float32))
    t = jnp.asarray(rng.standard_normal((B, S, H, D)).astype(np.float32))

    def loss_kernel(q, k, v):
        o = flash_attention_trainable(q, k, v, causal, window, 64, 64, True)
        return (o.astype(jnp.float32) * t).sum()

    def loss_ref(q, k, v):
        o = attention_ref(q, k, v, causal=causal, window=window)
        return (o.astype(jnp.float32) * t).sum()

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gk, gr, "q k v".split()):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3,
                                   err_msg=f"d{name} mismatch")


def test_flash_fwd_value_through_vjp_wrapper():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 128, 2, 32)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((1, 128, 2, 32)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((1, 128, 2, 32)).astype(np.float32))
    o = flash_attention_trainable(q, k, v, True, 0, 64, 64, True)
    want = attention_ref(q, k, v, causal=True, window=0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
