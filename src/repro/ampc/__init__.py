"""Unified AMPC session API.

One entry point for every algorithm the paper studies::

    from repro.ampc import AmpcEngine
    res = AmpcEngine(dht_backend="routed").solve(g, "msf")

See README.md in this directory for the engine / registry / backend design
and the deprecation path for the old per-module functions.
"""
from .backends import DhtBackend, LocalDht, RoutedDht, resolve_backend
from .engine import AmpcEngine, AmpcResult, SolveContext
from .registry import ProblemSpec, get as get_problem, names as problem_names, \
    problem, specs as problem_specs

__all__ = [
    "AmpcEngine", "AmpcResult", "SolveContext",
    "DhtBackend", "LocalDht", "RoutedDht", "resolve_backend",
    "ProblemSpec", "problem", "get_problem", "problem_names", "problem_specs",
]
