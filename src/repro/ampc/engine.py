"""``AmpcEngine`` — one entry point for every AMPC algorithm in the repo.

    from repro.ampc import AmpcEngine
    eng = AmpcEngine(dht_backend="local", epsilon=0.5, seed=0)
    res = eng.solve(graph, "mis")
    res.output                  # bool (n,) membership mask
    res.ledger["shuffles"]      # Table-3 materialized round count
    res.stats                   # algorithm-specific stats, stable key names

The engine owns the three things every pre-engine call site threaded by
hand: the ``RoundLedger`` (created per solve, summarized on the result),
the DHT backend (local gather vs routed all_to_all — pluggable, identical
accounting), and the seed/epsilon defaults.  Problems are resolved through
:mod:`repro.ampc.registry`, so a new algorithm becomes engine-callable by
decorating its adapter with ``@problem(...)``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

from ..core.rounds import RoundLedger
from . import registry
from .backends import DhtBackend, resolve_backend


@dataclasses.dataclass
class AmpcResult:
    """Uniform result of ``AmpcEngine.solve``.

    ``output`` follows the problem's declared kind: ``vertex_mask`` (bool
    (n,)), ``edge_mask`` (bool (m,)), ``labels`` (int (n,)), or ``count``
    (int).  ``ledger`` is the ``RoundLedger.summary()`` dict —
    ``ledger["shuffles"]`` is the paper's Table-3 round count.
    """

    problem: str
    model: str                      # "ampc" | "mpc"
    backend: str                    # DHT backend name used for the solve
    output: Any
    stats: Dict[str, Any]
    ledger: Dict[str, Any]
    wall_time_s: float
    raw_ledger: RoundLedger = dataclasses.field(repr=False, default=None)

    @property
    def shuffles(self) -> int:
        return self.ledger["shuffles"]

    def __repr__(self):
        return (f"AmpcResult(problem={self.problem!r}, model={self.model!r}, "
                f"backend={self.backend!r}, shuffles={self.shuffles}, "
                f"dht_queries={self.ledger['dht_queries']}, "
                f"wall_time_s={self.wall_time_s:.3f})")


@dataclasses.dataclass
class SolveContext:
    """Cross-cutting state handed to every registered solver."""

    ledger: RoundLedger
    dht: DhtBackend
    seed: int
    epsilon: float
    mesh: Any = None


class AmpcEngine:
    """Session object for AMPC graph solves.

    Parameters
    ----------
    mesh:         optional jax mesh handed to the routed backend (a 1-D mesh
                  over all devices is built when omitted).
    dht_backend:  ``"local"`` | ``"routed"`` | a ``DhtBackend`` instance.
    epsilon:      the paper's space exponent (per-machine space n^ε).
    seed:         default randomness for rank permutations / sampling.
    """

    def __init__(self, mesh=None, dht_backend="local", epsilon: float = 0.5,
                 seed: int = 0):
        self.mesh = mesh
        self.dht = resolve_backend(dht_backend, mesh=mesh)
        self.epsilon = float(epsilon)
        self.seed = int(seed)

    # ------------------------------------------------------------------
    def solve(self, graph, problem: str, *, seed: Optional[int] = None,
              epsilon: Optional[float] = None, **opts) -> AmpcResult:
        """Run ``problem`` on ``graph`` and return an ``AmpcResult``.

        ``**opts`` are forwarded to the registered solver (e.g.
        ``skip_ternarize_if_dense=False`` for msf, ``p=1/64`` for
        one-vs-two).  ``seed``/``epsilon`` override the engine defaults for
        this solve only.
        """
        spec = registry.get(problem)
        if spec.needs_weights and getattr(graph, "weights", None) is None:
            raise ValueError(
                f"problem {spec.name!r} needs edge weights; call "
                "g.with_random_weights()/g.with_degree_weights() first")
        if spec.needs_cycles and not (graph.degrees() == 2).all():
            raise ValueError(
                f"problem {spec.name!r} needs a disjoint union of cycles "
                "(every vertex must have degree 2)")
        ledger = RoundLedger(f"{spec.model}_{spec.name}")
        ctx = SolveContext(
            ledger=ledger, dht=self.dht,
            seed=self.seed if seed is None else int(seed),
            epsilon=self.epsilon if epsilon is None else float(epsilon),
            mesh=self.mesh)
        t0 = time.perf_counter()
        output, stats = spec.fn(ctx, graph, **opts)
        wall = time.perf_counter() - t0
        return AmpcResult(problem=spec.name, model=spec.model,
                          backend=self.dht.name, output=output, stats=stats,
                          ledger=ledger.summary(), wall_time_s=wall,
                          raw_ledger=ledger)

    # ------------------------------------------------------------------
    def problems(self, model: Optional[str] = None):
        """Names of every solvable problem (optionally one model only)."""
        return registry.names(model)

    def baseline_for(self, problem: str) -> Optional[str]:
        """Name of the MPC baseline registered for an AMPC problem."""
        for spec in registry.specs("mpc"):
            if spec.baseline_of == registry.get(problem).name:
                return spec.name
        return None

    def __repr__(self):
        return (f"AmpcEngine(dht_backend={self.dht.name!r}, "
                f"epsilon={self.epsilon}, seed={self.seed})")
