"""§Perf variants preserve exact semantics (hillclimbs are lossless)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.layers import (attention_xla, attention_xla_chunked,
                                 make_attention_mask)
from repro.models.moe import MoeSpec, init_moe, moe_apply, moe_apply_local


@pytest.mark.parametrize("window,static_window", [(0, None), (64, 64),
                                                  (96, None)])
def test_static_skip_attention_exact(window, static_window):
    rng = np.random.default_rng(0)
    B, S, H, Hkv, D = 2, 512, 4, 2, 32
    q = jnp.asarray(rng.standard_normal((B, S, H, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, D)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    mask = make_attention_mask(pos, pos, window if window else None,
                               causal=True)
    want = attention_xla(q, k, v, mask[:, None, None, :, :])
    got = attention_xla_chunked(
        q, k, v, pos, pos, window=jnp.int32(window), causal=True,
        chunk_q=128, chunk_kv=128, static_positions=True,
        static_window=static_window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_static_skip_gradients_match():
    rng = np.random.default_rng(1)
    B, S, H, D = 1, 256, 2, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, S, H, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, S, H, D)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))

    def f_plain(q):
        mask = make_attention_mask(pos, pos, None, causal=True)
        return attention_xla(q, k, v, mask[:, None, None, :, :]).sum()

    def f_skip(q):
        return attention_xla_chunked(q, k, v, pos, pos, window=None,
                                     causal=True, chunk_q=64, chunk_kv=64,
                                     static_positions=True).sum()

    g1 = jax.grad(f_plain)(q)
    g2 = jax.grad(f_skip)(q)
    np.testing.assert_allclose(np.asarray(g2), np.asarray(g1),
                               rtol=1e-4, atol=1e-4)


def test_moe_local_dispatch_single_shard_equivalence():
    """dp_shards=1 must reproduce the global dispatch exactly."""
    spec = MoeSpec(d_model=32, d_ff=64, n_experts=4, top_k=2)
    params = init_moe(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    out_g, aux_g = moe_apply(params, x, spec)
    out_l, aux_l = moe_apply_local(params, x, spec, dp_shards=1)
    np.testing.assert_allclose(np.asarray(out_l), np.asarray(out_g),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(aux_l), float(aux_g), rtol=1e-5)


def test_moe_local_dispatch_sharded_is_valid():
    """Multi-shard dispatch: outputs finite, per-shard capacity honoured,
    aux loss in the balanced range."""
    spec = MoeSpec(d_model=16, d_ff=32, n_experts=4, top_k=1)
    params = init_moe(jax.random.PRNGKey(2), spec)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 32, 16))
    out, aux = moe_apply_local(params, x, spec, dp_shards=4)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert 0.5 < float(aux) < 4.0


def test_microbatched_train_step_matches_plain():
    """n_microbatches changes memory, not the final gradients (linear loss
    averaging) — losses must match closely."""
    import dataclasses
    from repro.configs.registry import REGISTRY
    from repro.launch import steps
    from repro.optim import adamw
    from repro.models import transformer as tr
    from repro.data.tokens import TokenStreamConfig, batch_at_step

    cfg = REGISTRY["qwen3-4b"].smoke_config
    opt_cfg = adamw.AdamWConfig()
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init_state(params)
    tk = TokenStreamConfig(cfg.vocab, 16, 4)
    tokens, labels = batch_at_step(tk, 0)
    p1, o1, m1 = steps.lm_train_step(cfg, opt_cfg, params, opt,
                                     jnp.asarray(tokens), jnp.asarray(labels))
    cfg2 = dataclasses.replace(cfg, n_microbatches=2)
    p2, o2, m2 = steps.lm_train_step(cfg2, opt_cfg, params, opt,
                                     jnp.asarray(tokens), jnp.asarray(labels))
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-3)
    l1 = jax.tree.leaves(p1)
    l2 = jax.tree.leaves(p2)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-2, atol=5e-3)
