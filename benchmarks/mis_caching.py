"""Figure 4 reproduction: effect of the caching optimization on AMPC MIS/MM
KV-store traffic (the multithreading optimization has no TPU analogue —
batched gathers are already parallel; see DESIGN.md §2)."""
from __future__ import annotations

from repro.core import matching as mm, mis

from .common import GRAPHS, fmt_table


def run(graph_names=None):
    names = graph_names or list(GRAPHS)
    rows = []
    for gname in names:
        g = GRAPHS[gname]()
        _, st = mis.mis_ampc(g, seed=0)
        _, stm = mm.mm_ampc(g, seed=0)
        rows.append([gname,
                     st["queries_nodedup"], st["queries_dedup"],
                     f"{st['cache_savings_factor']:.2f}x",
                     stm["queries_nodedup"], stm["queries_dedup"],
                     f"{stm['queries_nodedup']/max(stm['queries_dedup'],1):.2f}x"])
    out = fmt_table(["graph", "MIS q (no cache)", "MIS q (cache)", "MIS save",
                     "MM q (no cache)", "MM q (cache)", "MM save"], rows)
    print(out)
    print("\npaper Fig 4: caching reduces KV bytes 1.96-12.2x (MIS), "
          "2.65-8.81x (MM)")
    return {"rows": rows, "markdown": out}


if __name__ == "__main__":
    run()
