"""``AmpcEngine`` — one entry point for every AMPC algorithm in the repo.

    from repro.ampc import AmpcEngine
    eng = AmpcEngine(dht_backend="local", epsilon=0.5, seed=0)
    res = eng.solve(graph, "mis")
    res.output                  # bool (n,) membership mask
    res.ledger["shuffles"]      # Table-3 materialized round count
    res.stats                   # algorithm-specific stats, stable key names

The engine owns the three things every pre-engine call site threaded by
hand: the ``RoundLedger`` (created per solve, summarized on the result),
the DHT backend (local gather vs routed all_to_all — pluggable, identical
accounting), and the seed/epsilon defaults.  Problems are resolved through
:mod:`repro.ampc.registry`, so a new algorithm becomes engine-callable by
decorating its adapter with ``@problem(...)``.

For serving many graphs per call, :meth:`AmpcEngine.solve_many` pads the
fleet into power-of-two shape buckets and runs each bucket as one vmapped
launch, memoizing the traced solver per ``(problem, backend, bucket)`` in
an engine-level :class:`~repro.ampc.cache.SolverCache`
(see :meth:`AmpcEngine.cache_info`).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from ..core.rounds import RoundLedger
from ..graph import batching
from . import registry
from .backends import DhtBackend, resolve_backend
from .cache import CacheInfo, SolverCache


def _field_eq(a, b) -> bool:
    """Equality that tolerates numpy arrays nested in outputs/stats."""
    if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
        return np.array_equal(a, b)
    if isinstance(a, dict) and isinstance(b, dict):
        return a.keys() == b.keys() and \
            all(_field_eq(a[k], b[k]) for k in a)
    if isinstance(a, (list, tuple)) and isinstance(b, (list, tuple)):
        return len(a) == len(b) and \
            all(_field_eq(x, y) for x, y in zip(a, b))
    return a == b


@dataclasses.dataclass(eq=False)
class AmpcResult:
    """Uniform result of ``AmpcEngine.solve`` / ``AmpcEngine.solve_many``.

    ``output`` follows the problem's declared kind: ``vertex_mask`` (bool
    (n,)), ``edge_mask`` (bool (m,)), ``labels`` (int (n,)), or ``count``
    (int).  ``ledger`` is the ``RoundLedger.summary()`` dict —
    ``ledger["shuffles"]`` is the paper's Table-3 round count.

    ``raw_ledger`` keeps the live ledger for phase-time inspection; it is
    excluded from equality (``compare=False``), and ``__eq__`` compares the
    remaining fields with array-aware semantics, so results holding numpy
    outputs compare cleanly instead of raising.

    >>> from repro.ampc import AmpcEngine
    >>> from repro.graph import generators as gen
    >>> res = AmpcEngine(seed=0).solve(gen.erdos_renyi(64, 3.0, seed=1), "mis")
    >>> res.problem, res.model, res.backend
    ('mis', 'ampc', 'local')
    >>> res.shuffles == res.ledger["shuffles"] == 2
    True
    >>> bool(res.output.any())
    True
    """

    problem: str
    model: str                      # "ampc" | "mpc"
    backend: str                    # DHT backend name used for the solve
    output: Any
    stats: Dict[str, Any]
    ledger: Dict[str, Any]
    wall_time_s: float
    raw_ledger: Optional[RoundLedger] = dataclasses.field(
        repr=False, compare=False, default=None)

    @property
    def shuffles(self) -> int:
        return self.ledger["shuffles"]

    def __eq__(self, other):
        if not isinstance(other, AmpcResult):
            return NotImplemented
        return all(_field_eq(getattr(self, f.name), getattr(other, f.name))
                   for f in dataclasses.fields(self) if f.compare)

    def __repr__(self):
        return (f"AmpcResult(problem={self.problem!r}, model={self.model!r}, "
                f"backend={self.backend!r}, shuffles={self.shuffles}, "
                f"dht_queries={self.ledger['dht_queries']}, "
                f"wall_time_s={self.wall_time_s:.3f})")


@dataclasses.dataclass
class SolveContext:
    """Cross-cutting state handed to every registered solver."""

    ledger: RoundLedger
    dht: DhtBackend
    seed: int
    epsilon: float
    mesh: Any = None


@dataclasses.dataclass
class BatchSolveContext:
    """Cross-cutting state handed to a batch adapter for one bucket launch.

    ``ledgers`` holds one ``RoundLedger`` per graph in the batch (batch
    order): the single physical launch is attributed per graph — each ledger
    records the bucket's shuffle structure with that graph's own bytes and
    its own share of the DHT query counts (split by mask).
    """

    ledgers: List[RoundLedger]
    dht: DhtBackend
    seed: int
    epsilon: float
    cache: SolverCache
    problem: str = ""
    backend_name: str = ""
    mesh: Any = None

    def solver_key(self, batch, *extra):
        """Cache key for this bucket's compiled solver.  ``extra`` captures
        options that change the traced program (e.g. a static walk budget)."""
        return (self.problem, self.backend_name,
                batch.n_bucket, batch.m_bucket, *extra)


class AmpcEngine:
    """Session object for AMPC graph solves.

    Parameters
    ----------
    mesh:         optional jax mesh handed to the routed backend (a 1-D mesh
                  over all devices is built when omitted).
    dht_backend:  ``"local"`` | ``"routed"`` | a ``DhtBackend`` instance.
    epsilon:      the paper's space exponent (per-machine space n^ε).
    seed:         default randomness for rank permutations / sampling.

    >>> from repro.ampc import AmpcEngine
    >>> from repro.graph import generators as gen
    >>> eng = AmpcEngine(dht_backend="local", epsilon=0.5, seed=0)
    >>> fleet = [gen.erdos_renyi(48, 3.0, seed=s) for s in range(3)]
    >>> results = eng.solve_many(fleet, "mis")
    >>> [r.problem for r in results]
    ['mis', 'mis', 'mis']
    >>> sequential = eng.solve(fleet[0], "mis")
    >>> bool((results[0].output == sequential.output).all())
    True
    >>> eng.cache_info().misses >= 1
    True
    """

    def __init__(self, mesh=None, dht_backend="local", epsilon: float = 0.5,
                 seed: int = 0):
        self.mesh = mesh
        self.dht = resolve_backend(dht_backend, mesh=mesh)
        self.epsilon = float(epsilon)
        self.seed = int(seed)
        self._solver_cache = SolverCache()

    # ------------------------------------------------------------------
    def _validate(self, spec, graph) -> None:
        if spec.needs_weights and getattr(graph, "weights", None) is None:
            raise ValueError(
                f"problem {spec.name!r} needs edge weights; call "
                "g.with_random_weights()/g.with_degree_weights() first")
        if spec.needs_cycles and not (graph.degrees() == 2).all():
            raise ValueError(
                f"problem {spec.name!r} needs a disjoint union of cycles "
                "(every vertex must have degree 2)")

    # ------------------------------------------------------------------
    def solve(self, graph, problem: str, *, seed: Optional[int] = None,
              epsilon: Optional[float] = None, **opts) -> AmpcResult:
        """Run ``problem`` on ``graph`` and return an ``AmpcResult``.

        ``**opts`` are forwarded to the registered solver (e.g.
        ``skip_ternarize_if_dense=False`` for msf, ``p=1/64`` for
        one-vs-two).  ``seed``/``epsilon`` override the engine defaults for
        this solve only.
        """
        spec = registry.get(problem)
        self._validate(spec, graph)
        ledger = RoundLedger(f"{spec.model}_{spec.name}")
        ctx = SolveContext(
            ledger=ledger, dht=self.dht,
            seed=self.seed if seed is None else int(seed),
            epsilon=self.epsilon if epsilon is None else float(epsilon),
            mesh=self.mesh)
        t0 = time.perf_counter()
        output, stats = spec.fn(ctx, graph, **opts)
        wall = time.perf_counter() - t0
        return AmpcResult(problem=spec.name, model=spec.model,
                          backend=self.dht.name, output=output, stats=stats,
                          ledger=ledger.summary(), wall_time_s=wall,
                          raw_ledger=ledger)

    # ------------------------------------------------------------------
    def solve_many(self, graphs: Sequence[Any], problem: str, *,
                   seed: Optional[int] = None,
                   epsilon: Optional[float] = None,
                   **opts) -> List[AmpcResult]:
        """Solve ``problem`` on a fleet of graphs, one result per graph.

        Graphs are padded into power-of-two ``(n_bucket, m_bucket)`` shape
        buckets (:mod:`repro.graph.batching`); each bucket runs as a single
        vmapped/jitted launch whose traced solver is memoized in the
        engine's :class:`SolverCache`, so repeated traffic on same-sized
        graphs skips tracing entirely.  Outputs are unpadded back to
        per-graph ``AmpcResult`` objects identical to sequential ``solve``
        outputs; ``wall_time_s`` is the bucket launch amortized over its
        occupants.

        Problems without a registered batch adapter (see
        ``src/repro/ampc/README.md`` for the list) fall back to sequential
        ``solve`` calls — same results, no batching speedup.
        """
        graphs = list(graphs)
        spec = registry.get(problem)
        for g in graphs:
            self._validate(spec, g)
        if spec.batch_fn is None:
            return [self.solve(g, problem, seed=seed, epsilon=epsilon, **opts)
                    for g in graphs]
        results: List[Optional[AmpcResult]] = [None] * len(graphs)
        for batch in batching.bucketize(graphs).values():
            ledgers = [RoundLedger(f"{spec.model}_{spec.name}")
                       for _ in range(len(batch))]
            bctx = BatchSolveContext(
                ledgers=ledgers, dht=self.dht,
                seed=self.seed if seed is None else int(seed),
                epsilon=self.epsilon if epsilon is None else float(epsilon),
                cache=self._solver_cache, problem=spec.name,
                backend_name=self.dht.name, mesh=self.mesh)
            t0 = time.perf_counter()
            outs = spec.batch_fn(bctx, batch, **opts)
            wall = time.perf_counter() - t0
            assert len(outs) == len(batch), \
                f"batch adapter for {spec.name!r} returned {len(outs)} " \
                f"results for {len(batch)} graphs"
            per_graph_wall = wall / max(len(batch), 1)
            for slot, (idx, (output, stats)) in enumerate(
                    zip(batch.indices, outs)):
                stats.setdefault("batch", {
                    "bucket": batch.key, "batch_size": len(batch),
                    "slot": slot})
                results[idx] = AmpcResult(
                    problem=spec.name, model=spec.model,
                    backend=self.dht.name, output=output, stats=stats,
                    ledger=ledgers[slot].summary(),
                    wall_time_s=per_graph_wall, raw_ledger=ledgers[slot])
        return results

    # ------------------------------------------------------------------
    def cache_info(self) -> CacheInfo:
        """Hit/miss/size counters of the compiled-solver cache.

        One miss per solver actually traced; one hit per graph served by an
        already-traced solver (so a cold bucket of ``B`` graphs counts
        ``1`` miss and ``B - 1`` hits).
        """
        return self._solver_cache.info()

    def clear_cache(self) -> None:
        """Drop every memoized solver and reset the hit/miss counters."""
        self._solver_cache.clear()

    # ------------------------------------------------------------------
    def problems(self, model: Optional[str] = None):
        """Names of every solvable problem (optionally one model only)."""
        return registry.names(model)

    def baseline_for(self, problem: str) -> Optional[str]:
        """Name of the MPC baseline registered for an AMPC problem."""
        for spec in registry.specs("mpc"):
            if spec.baseline_of == registry.get(problem).name:
                return spec.name
        return None

    def __repr__(self):
        return (f"AmpcEngine(dht_backend={self.dht.name!r}, "
                f"epsilon={self.epsilon}, seed={self.seed})")
