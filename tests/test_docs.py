"""Docs-drift guards: the READMEs must track the registry, and the engine
docstring examples must actually run.

Checks:
  * every problem name in ``src/repro/ampc/README.md``'s "Registered
    problems" section resolves in the registry, and every registered name
    appears there (bidirectional — the docs cannot silently rot);
  * the batch-safe problem list in the "Batched serving" section matches
    the set of registered batch adapters;
  * the top-level README's python quickstart blocks parse;
  * the doctest examples in ``repro/ampc/engine.py`` execute cleanly
    (the same examples ``pytest --doctest-modules src/repro/ampc/engine.py``
    runs standalone).
"""
import doctest
import re
from pathlib import Path

import pytest

from repro.ampc import registry

REPO = Path(__file__).resolve().parent.parent
AMPC_README = REPO / "src" / "repro" / "ampc" / "README.md"
TOP_README = REPO / "README.md"

_NAME = re.compile(r"`([a-z0-9][a-z0-9-]*)`")


def _strip_fenced_blocks(text: str) -> str:
    return re.sub(r"```.*?```", "", text, flags=re.S)


def _section(text: str, header: str) -> str:
    m = re.search(rf"^##\s+{re.escape(header)}\s*$(.*?)(?=^##\s|\Z)",
                  text, re.S | re.M)
    assert m, f"section {header!r} missing from {AMPC_README}"
    return m.group(1)


def test_ampc_readme_problem_list_matches_registry():
    text = AMPC_README.read_text()
    section = _strip_fenced_blocks(_section(text, "Registered problems"))
    listed = set(_NAME.findall(section))
    assert listed, "no problem names found in the Registered problems section"
    # every listed token resolves (canonical names and aliases alike) ...
    for name in sorted(listed):
        try:
            registry.get(name)
        except KeyError:
            pytest.fail(f"README lists unknown problem/alias {name!r}")
    # ... and every registered problem is listed under its canonical name
    for name in registry.names():
        assert name in listed, f"registered problem {name!r} missing from " \
            f"{AMPC_README}'s Registered problems section"


def test_ampc_readme_batch_safe_list_matches_registry():
    section = _section(AMPC_README.read_text(), "Batched serving: `solve_many`")
    m = re.search(r"\*\*Batch-safe problems\*\*[^:]*:\s*(.*?)\.", section,
                  re.S)
    assert m, "Batch-safe problems sentence missing"
    listed = {t for t in _NAME.findall(m.group(1))}
    batched = {s.name for s in registry.specs() if s.batch_fn is not None}
    assert listed == batched, (
        f"README batch-safe list {sorted(listed)} != registered batch "
        f"adapters {sorted(batched)}")


def test_ampc_readme_module_table_covers_package():
    text = AMPC_README.read_text()
    pkg = AMPC_README.parent
    modules = {p.name for p in pkg.glob("*.py") if p.name != "__init__.py"}
    for mod in sorted(modules):
        assert f"`{mod}`" in text, f"{mod} missing from the module table"


def test_top_readme_quickstart_blocks_parse():
    text = TOP_README.read_text()
    blocks = re.findall(r"```python\n(.*?)```", text, re.S)
    assert blocks, "README.md has no python quickstart blocks"
    for i, block in enumerate(blocks):
        compile(block, f"README.md:block{i}", "exec")


def test_top_readme_links_resolve():
    text = TOP_README.read_text()
    for target in re.findall(r"\]\(([^)#]+)\)", text):
        if target.startswith("http"):
            continue
        assert (REPO / target).exists(), f"README links to missing {target}"


def test_engine_docstring_examples_execute():
    from repro.ampc import engine
    result = doctest.testmod(engine, verbose=False)
    assert result.attempted >= 8, "engine.py doctest examples went missing"
    assert result.failed == 0


def test_architecture_metric_table_matches_engine_metrics():
    """The Observability metric table in docs/architecture.md must stay in
    sync with the canonical obs.metrics.ENGINE_METRICS definitions
    (name, kind, and label set per metric, bidirectionally)."""
    from repro.obs.metrics import ENGINE_METRICS

    text = (REPO / "docs" / "architecture.md").read_text()
    section = re.search(r"### Metric names.*?\n\n(\|.*?)\n\n", text, re.S)
    assert section, "Metric names table missing from docs/architecture.md"
    rows = [r for r in section.group(1).splitlines()
            if r.startswith("| `")]
    code = re.compile(r"`([a-z0-9_]+)`")
    documented = {}
    for row in rows:
        cells = [c.strip() for c in row.strip("|").split("\\|")[0].split("|")]
        name = cells[0].strip("`")
        documented[name] = (cells[1], tuple(code.findall(cells[2])))
    assert set(documented) == set(ENGINE_METRICS), (
        f"docs table metrics {sorted(documented)} != ENGINE_METRICS "
        f"{sorted(ENGINE_METRICS)}")
    for name, mdef in ENGINE_METRICS.items():
        kind, labels = documented[name]
        assert kind == mdef.kind, f"{name}: docs say {kind}, code {mdef.kind}"
        assert labels == mdef.labels, \
            f"{name}: docs labels {labels} != code labels {mdef.labels}"


def test_architecture_ledger_metric_map_resolves():
    """Every row of the ledger→metrics map must name a real RoundLedger
    field and a declared metric."""
    from repro.core.rounds import RoundLedger
    from repro.obs.metrics import ENGINE_METRICS

    text = (REPO / "docs" / "architecture.md").read_text()
    section = re.search(r"### Ledger → metrics map.*?\n\n.*?\n\n(\|.*?)\n\n",
                        text, re.S)
    assert section, "Ledger → metrics map missing from docs/architecture.md"
    ledger_fields = {f.name for f in
                     __import__("dataclasses").fields(RoundLedger)}
    rows = [re.findall(r"`([a-z0-9_]+)`", r)
            for r in section.group(1).splitlines() if r.startswith("| `")]
    rows = [r for r in rows if len(r) >= 2]
    assert len(rows) >= 7
    for field, metric in rows:
        assert field in ledger_fields, f"unknown ledger field {field!r}"
        assert metric in ENGINE_METRICS, f"unknown metric {metric!r}"


def test_accounting_model_docs_in_sync():
    """The Accounting model section must name the real deferred-ledger
    surface, and its claims must resolve against the code: the hook
    global, the harvest methods, the impl switch, and the lint script."""
    import jax

    from repro.ampc.engine import AmpcEngine
    from repro.core import dht, rounds

    text = (REPO / "docs" / "architecture.md").read_text()
    m = re.search(r"^##\s+Accounting model\s*$(.*?)(?=^##\s|\Z)", text,
                  re.S | re.M)
    assert m, "Accounting model section missing from docs/architecture.md"
    section = m.group(1)
    for token in ("DeviceCounters", "record_queries_deferred", "harvest",
                  "harvest_many", "HARVEST_HOOK", "current_span",
                  "deferred_accounting=False", "deferred=True",
                  'impl="take"|"pallas"', "scripts/lint_host_sync.py",
                  "BENCH_dht_hot_path.json", "# host-sync: ok"):
        assert token in section, (
            f"{token!r} missing from the Accounting model section")
    # the documented surface exists
    assert hasattr(rounds, "HARVEST_HOOK")
    assert hasattr(rounds, "harvest_many")
    assert callable(rounds.RoundLedger.harvest)
    assert callable(rounds.RoundLedger.record_queries_deferred)
    assert rounds.DeviceCounters is not None
    assert "deferred_accounting" in AmpcEngine.__init__.__code__.co_varnames
    # documented default: engine ledgers are deferred, bare ledgers eager
    assert rounds.RoundLedger("x").deferred is False
    # documented impl default resolves by platform
    expect = "pallas" if jax.default_backend() == "tpu" else "take"
    assert dht.ShardedDHT(__import__("jax.numpy", fromlist=["jnp"])
                          .arange(2)).impl == expect
    assert (REPO / "scripts" / "lint_host_sync.py").exists()
    check = (REPO / "scripts" / "check.sh").read_text()
    assert "lint_host_sync.py" in check, (
        "lint_host_sync.py not wired into scripts/check.sh")


def test_async_serving_docs_in_sync():
    """The Async serving docs must name the real engine surface, and the
    ampc README's snapshot-problem list must match SNAPSHOT_PROBLEMS."""
    from repro.ampc import AmpcEngine, SNAPSHOT_PROBLEMS

    arch = (REPO / "docs" / "architecture.md").read_text()
    m = re.search(r"^##\s+Async serving\s*$(.*?)(?=^##\s|\Z)", arch,
                  re.S | re.M)
    assert m, "Async serving section missing from docs/architecture.md"
    section = m.group(1)
    for token in ("submit", "shutdown", "session", "cache_info",
                  "engine_async_inflight", "solve[async]", "queue_wait",
                  "WriteGraphKV"):
        assert token in section, f"{token!r} missing from Async serving docs"
    for api in ("submit", "submit_many", "shutdown", "session"):
        assert callable(getattr(AmpcEngine, api)), api
    readme_section = _section(
        AMPC_README.read_text(),
        "Async serving: `submit` and `GraphSession`")
    for name in sorted(SNAPSHOT_PROBLEMS):
        assert f"`{name}`" in readme_section, (
            f"snapshot-aware problem {name!r} missing from the ampc "
            "README's Async serving section")


def test_architecture_snapshot_docs_in_sync():
    """The GraphSession snapshot docs must name every snapshot-aware
    problem and the view-building shuffles of the view-keyed layout, and
    the session module docstring must name the same problem set."""
    from repro.ampc import SNAPSHOT_PROBLEMS
    from repro.ampc import session as session_mod

    text = (REPO / "docs" / "architecture.md").read_text()
    m = re.search(r"^###\s+Snapshot reuse: `GraphSession`\s*$(.*?)"
                  r"(?=^#{2,3}\s|\Z)", text, re.S | re.M)
    assert m, "Snapshot reuse section missing from docs/architecture.md"
    section = m.group(1)
    for name in sorted(SNAPSHOT_PROBLEMS):
        assert f"`{name}`" in section, (
            f"snapshot-aware problem {name!r} missing from the "
            "architecture snapshot section")
    for token in ("WriteGraphKV", "WriteTernKV", "SNAPSHOT_PROBLEMS",
                  "view-keyed"):
        assert token in section, (
            f"{token!r} missing from the architecture snapshot section")
    doc = session_mod.__doc__ or ""
    for name in sorted(SNAPSHOT_PROBLEMS):
        assert f"``{name}``" in doc, (
            f"snapshot-aware problem {name!r} missing from the session.py "
            "module docstring")
    # the batched-msf note rides in the solve_many anatomy section
    anatomy = re.search(r"^##\s+Anatomy of a `solve_many` bucket launch\s*$"
                        r"(.*?)(?=^##\s|\Z)", text, re.S | re.M)
    assert anatomy, "solve_many anatomy section missing"
    assert "`msf`" in anatomy.group(1), (
        "batched msf not documented in the solve_many anatomy section")


def test_benchmark_registry_docstring_matches_dispatch():
    """benchmarks/registry.py documents the @bench contract; the registered
    specs must actually follow it (run(**kwargs) plus quick_kwargs that the
    harness can splat)."""
    import sys
    sys.path.insert(0, str(REPO))
    from benchmarks import registry as breg
    for name in breg.names():
        spec = breg.get(name)
        assert callable(spec.fn), name
        assert isinstance(spec.quick_kwargs, dict), name
