"""Deferred (device-resident) ledger accounting: bit-exactness + sync count.

The hot-path contract introduced with ``RoundLedger(deferred=True)``:

  1. Counter totals after a deferred solve's single harvest equal the
     eager per-lookup totals bit for bit — on both DHT execution schedules
     (local gather and the shard_map router) and for every engine problem.
  2. ``impl="pallas"`` (cached-gather kernel) and ``impl="take"`` produce
     bit-identical lookup outputs *and* ledger counters.
  3. A warm ``engine.solve`` performs exactly ONE device->host harvest,
     observed through the ``rounds.HARVEST_HOOK`` test hook; a warm
     single-bucket ``solve_many`` also performs exactly one.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.ampc import AmpcEngine
from repro.core import dht, rounds
from repro.core.rounds import RoundLedger
from repro.graph import generators as gen
from repro.graph.coo import UGraph

COUNTERS = ("shuffles", "bytes_shuffled", "dht_queries", "dht_bytes",
            "dht_query_waves", "dedup_savings", "dht_overflows")


def counters(ledger):
    # accepts a live RoundLedger or the summary dict AmpcResult carries
    summ = ledger if isinstance(ledger, dict) else ledger.summary()
    return {k: summ[k] for k in COUNTERS}


def _random_graph(draw):
    n = draw(st.integers(6, 40))
    m = draw(st.integers(0, 80))
    rng = np.random.default_rng(draw(st.integers(0, 10_000)))
    e = rng.integers(0, n, (m, 2)).astype(np.int32)
    return UGraph(n, e).dedup()


# ---------------------------------------------------------------- DHT level


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_deferred_counters_bit_identical_local(data):
    nvals = data.draw(st.integers(1, 50))
    keys = np.array(
        data.draw(st.lists(st.integers(-1, 60), min_size=1, max_size=100)),
        np.int32)
    values = jnp.arange(nvals, dtype=jnp.int32) * 3
    dedup = data.draw(st.integers(0, 1)) == 1

    eager, deferred = RoundLedger("e"), RoundLedger("d", deferred=True)
    out_e = dht.ShardedDHT(values, ledger=eager).lookup(keys, dedup=dedup)
    out_d = dht.ShardedDHT(values, ledger=deferred).lookup(keys, dedup=dedup)
    deferred.harvest()
    assert np.array_equal(np.asarray(out_e), np.asarray(out_d))
    assert counters(eager) == counters(deferred)


@settings(max_examples=10, deadline=None)
@given(st.data())
def test_deferred_counters_bit_identical_routed(data):
    mesh = jax.make_mesh((len(jax.devices()),), ("dht",))
    nvals = data.draw(st.integers(2, 40))
    keys = np.array(
        data.draw(st.lists(st.integers(-1, 50), min_size=1, max_size=60)),
        np.int32)
    values = jnp.arange(nvals, dtype=jnp.int32)

    eager, deferred = RoundLedger("e"), RoundLedger("d", deferred=True)
    out_e = dht.ShardedDHT(values, ledger=eager, mesh=mesh,
                           axis_name="dht").lookup(keys)
    out_d = dht.ShardedDHT(values, ledger=deferred, mesh=mesh,
                           axis_name="dht").lookup(keys)
    deferred.harvest()
    assert np.array_equal(np.asarray(out_e), np.asarray(out_d))
    assert counters(eager) == counters(deferred)


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_pallas_vs_take_bit_identical(data):
    nvals = data.draw(st.integers(1, 60))
    keys = np.array(
        data.draw(st.lists(st.integers(-2, 80), min_size=1, max_size=120)),
        np.int32)
    wide = data.draw(st.integers(0, 1)) == 1
    values = (jnp.arange(nvals * 3, dtype=jnp.int32).reshape(nvals, 3)
              if wide else jnp.arange(nvals, dtype=jnp.int32) * 7)

    led_t, led_p = (RoundLedger("t", deferred=True),
                    RoundLedger("p", deferred=True))
    out_t = dht.ShardedDHT(values, ledger=led_t, impl="take").lookup(keys)
    out_p = dht.ShardedDHT(values, ledger=led_p, impl="pallas").lookup(keys)
    led_t.harvest(), led_p.harvest()
    assert np.array_equal(np.asarray(out_t), np.asarray(out_p))
    assert counters(led_t) == counters(led_p)


@pytest.mark.parametrize("impl", ["take", "pallas"])
def test_zero_length_query_batch(impl):
    # n=0 lanes appear once masked msf buckets land: lookups must not crash
    # on either impl, and counters must report zeros
    for deferred in (False, True):
        led = RoundLedger("z", deferred=deferred)
        values = jnp.arange(6, dtype=jnp.int32) * 2
        out = dht.ShardedDHT(values, ledger=led,
                             impl=impl).lookup(np.zeros((0,), np.int32))
        led.harvest()
        assert out.shape == (0,)
        assert led.dht_queries == 0 and led.dht_bytes == 0
    # wide values keep their row shape
    wide = jnp.arange(12, dtype=jnp.int32).reshape(6, 2)
    out = dht.ShardedDHT(wide, impl=impl).lookup(np.zeros((0,), np.int32))
    assert out.shape == (0, 2)


def test_dedup_keys_zero_length():
    uniq, inv, n_unique = dht.dedup_keys(jnp.zeros((0,), jnp.int32))
    assert uniq.shape == (0,) and inv.shape == (0,)
    assert int(n_unique) == 0


def test_impl_validation_and_default():
    values = jnp.arange(4, dtype=jnp.int32)
    with pytest.raises(ValueError, match="impl"):
        dht.ShardedDHT(values, impl="magic")
    expect = "pallas" if jax.default_backend() == "tpu" else "take"
    assert dht.ShardedDHT(values).impl == expect


def test_eager_ledger_still_counts_immediately():
    # deferred=False (the dataclass default) keeps the old contract: counters
    # are host-readable right after the lookup, no harvest call needed.
    led = RoundLedger("bare")
    dht.ShardedDHT(jnp.arange(8, dtype=jnp.int32),
                   ledger=led).lookup(np.array([1, 1, 2], np.int32))
    assert led.dht_queries == 2 and led.dedup_savings == 1
    assert led.harvest() is None  # nothing pending


def test_harvest_returns_extra_payload():
    led = RoundLedger("x", deferred=True)
    dht.ShardedDHT(jnp.arange(8, dtype=jnp.int32),
                   ledger=led).lookup(np.array([3, 3, 5], np.int32))
    out, total = led.harvest((jnp.int32(11), jnp.arange(3)))
    assert int(out) == 11 and np.array_equal(np.asarray(total), [0, 1, 2])
    assert led.dht_queries == 2


# ------------------------------------------------------------- engine level


@settings(max_examples=6, deadline=None)
@given(st.data())
def test_engine_deferred_matches_eager(data):
    g = _random_graph(data.draw)
    algo = ("mis", "matching", "connectivity")[data.draw(st.integers(0, 2))]
    seed = data.draw(st.integers(0, 1000))
    res_d = AmpcEngine(seed=seed).solve(g, algo)
    res_e = AmpcEngine(seed=seed, deferred_accounting=False).solve(g, algo)
    assert np.array_equal(np.asarray(res_d.output), np.asarray(res_e.output))
    assert counters(res_d.ledger) == counters(res_e.ledger)


def test_engine_routed_deferred_matches_local():
    g = gen.erdos_renyi(48, 3.0, seed=5)
    for algo in ("mis", "connectivity"):
        r = AmpcEngine(seed=0, dht_backend="routed").solve(g, algo)
        e = AmpcEngine(seed=0, dht_backend="routed",
                       deferred_accounting=False).solve(g, algo)
        loc = AmpcEngine(seed=0).solve(g, algo)
        assert counters(r.ledger) == counters(e.ledger) == counters(loc.ledger)


@pytest.fixture
def harvest_log():
    calls = []
    rounds.HARVEST_HOOK = lambda who: calls.append(who)
    try:
        yield calls
    finally:
        rounds.HARVEST_HOOK = None


def _graph_for(algo):
    if algo == "one-vs-two":
        return gen.two_cycles(24)
    g = gen.erdos_renyi(56, 3.0, seed=2)
    return g.with_random_weights(seed=3) if algo == "msf" else g


def test_warm_solve_single_harvest(harvest_log):
    eng = AmpcEngine(seed=0)
    for algo in ("mis", "matching", "connectivity", "one-vs-two", "msf"):
        eng.solve(_graph_for(algo), algo)
        harvest_log.clear()
        eng.solve(_graph_for(algo), algo)
        assert len(harvest_log) == 1, (algo, len(harvest_log))


def test_warm_solve_many_single_harvest_per_bucket(harvest_log):
    fleet = [gen.erdos_renyi(40, 3.0, seed=s) for s in range(4)]
    eng = AmpcEngine(seed=0)
    eng.solve_many(fleet, "mis")
    harvest_log.clear()
    results = eng.solve_many(fleet, "mis")
    assert len(results) == 4
    assert len(harvest_log) == 1


def test_warm_solve_many_msf_single_harvest_per_bucket(harvest_log):
    # one shape bucket mixing dense and sparse lanes: the two sub-launches
    # must still materialize through ONE harvest
    fleet = [gen.erdos_renyi(40, 2.0 if s % 2 else 10.0,
                             seed=s).with_random_weights(seed=s)
             for s in range(4)]
    from repro.graph.batching import bucketize
    eng = AmpcEngine(seed=0)
    eng.solve_many(fleet, "msf")
    harvest_log.clear()
    results = eng.solve_many(fleet, "msf")
    assert len(results) == 4
    assert {r.stats["path"] for r in results} == {"sparse", "dense"}
    assert len(harvest_log) == len(bucketize(fleet))


def test_session_warm_solve_single_harvest(harvest_log):
    g = gen.erdos_renyi(48, 3.0, seed=7)
    eng = AmpcEngine(seed=0)
    sess = eng.session(g)
    sess.solve("mis")
    harvest_log.clear()
    res = sess.solve("matching")
    assert res.stats["snapshot"]["hit"] is True
    assert len(harvest_log) == 1


def test_session_warm_msf_cc_single_harvest(harvest_log):
    g = gen.erdos_renyi(48, 2.0, seed=7).with_random_weights(seed=1)
    eng = AmpcEngine(seed=0)
    sess = eng.session(g)
    for algo in ("msf", "connectivity"):
        sess.solve(algo)
        harvest_log.clear()
        res = sess.solve(algo)
        assert res.stats["snapshot"]["hit"] is True
        assert res.ledger["shuffles"] == 1
        assert len(harvest_log) == 1, (algo, len(harvest_log))
