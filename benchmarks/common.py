"""Shared benchmark graph suite (CPU-scale stand-ins for the paper's
OK/TW/FS/CW/HL inputs) + reporting helpers."""
from __future__ import annotations

import time

from repro.graph import generators as gen

# name -> constructor (moderate sizes: every bench finishes on 1 CPU core)
GRAPHS = {
    "rmat14": lambda: gen.rmat(14, 8.0, seed=1),       # social-like, skewed
    "rmat12": lambda: gen.rmat(12, 16.0, seed=2),      # denser
    "er13": lambda: gen.erdos_renyi(8192, 6.0, seed=3),
    "er10": lambda: gen.erdos_renyi(1024, 4.0, seed=4),  # smoke-test scale
    "grid": lambda: gen.grid2d(90, 90),                # high diameter
}

# Default bench iteration: the paper-reproduction set. er10 exists only for
# the smoke test / explicit --graphs selection and is excluded so default
# runs keep producing the pre-registry tables.
DEFAULT_GRAPHS = [n for n in GRAPHS if n != "er10"]

# 1-vs-2-cycle sizes: the AMPC walk is a vmapped while_loop, so wall time on
# the 1-core CPU host is bounded by the longest inter-sample gap; 50k keeps
# the full benchmark run under a few minutes while preserving the scaling
# trend (the paper's 2e8-2e10 sizes are datacenter-scale).
CYCLES = {"2x2e3": 2_000, "2x1e4": 10_000, "2x5e4": 50_000}


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, time.perf_counter() - t0


def fmt_table(headers, rows) -> str:
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows))
              for i, h in enumerate(headers)]
    def line(cells):
        return " | ".join(str(c).ljust(w) for c, w in zip(cells, widths))
    sep = "-+-".join("-" * w for w in widths)
    return "\n".join([line(headers), sep] + [line(r) for r in rows])
