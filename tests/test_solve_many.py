"""``AmpcEngine.solve_many``: oracle parity, bucketing, cache, ledgers.

The acceptance gate for batched serving: on a fleet of mixed-size graphs,
``solve_many`` must return outputs **identical** to one sequential
``solve`` per graph for every batch-safe problem, with the compiled-solver
cache registering hits from the second bucket occupant on.
"""
import numpy as np
import pytest

from repro.ampc import AmpcEngine, AmpcResult
from repro.ampc.registry import get as get_problem
from repro.graph import generators as gen
from repro.graph.batching import (GraphBatch, bucket_shape, bucketize,
                                  next_pow2, pad_graphs)

BATCHED_PLAIN = ["mis", "matching", "connectivity"]

# 16 mixed-size graphs spanning several (n, m) shape buckets, with repeats
# inside buckets so the cache sees multi-occupant launches
FLEET_SIZES = [50, 60, 100, 120, 70, 50, 90, 110, 55, 65, 95, 115, 75, 85,
               105, 125]


def _fleet():
    return [gen.erdos_renyi(n, 3.0, seed=i)
            for i, n in enumerate(FLEET_SIZES)]


def _cycle_fleet():
    ks = [30, 40, 60, 30, 45, 50, 35, 55, 40, 30, 60, 45, 50, 35, 55, 30]
    return [gen.two_cycles(k) if i % 2 == 0 else gen.one_cycle(2 * k)
            for i, k in enumerate(ks)]


# --------------------------------------------------------------------------
# bucketing helpers
# --------------------------------------------------------------------------
def test_next_pow2_and_bucket_shape():
    assert [next_pow2(x) for x in (0, 1, 2, 3, 4, 5, 127, 128, 129)] == \
        [1, 1, 2, 4, 4, 8, 128, 128, 256]
    g = gen.erdos_renyi(100, 3.0, seed=0)
    nb, mb = bucket_shape(g.n, g.m)
    assert nb == 128 and mb == next_pow2(g.m)


def test_bucketize_preserves_order_and_pads():
    fleet = _fleet()
    buckets = bucketize(fleet)
    seen = sorted(i for b in buckets.values() for i in b.indices)
    assert seen == list(range(len(fleet)))
    for (nb, mb), batch in buckets.items():
        assert isinstance(batch, GraphBatch)
        assert batch.edges.shape == (len(batch), mb, 2)
        for b, g in enumerate(batch.graphs):
            assert bucket_shape(g.n, g.m) == (nb, mb)
            assert np.array_equal(batch.edges[b, :g.m], g.edges)
            assert not batch.edge_mask[b, g.m:].any()
            assert batch.node_mask[b, :g.n].all()
            assert not batch.node_mask[b, g.n:].any()


def test_pad_graphs_rejects_oversized():
    g = gen.erdos_renyi(100, 3.0, seed=0)
    with pytest.raises(AssertionError, match="exceeds bucket"):
        pad_graphs([g], [0], 64, 64)


# --------------------------------------------------------------------------
# oracle parity: solve_many == sequential solve, per problem
# --------------------------------------------------------------------------
@pytest.mark.parametrize("problem", BATCHED_PLAIN)
def test_solve_many_matches_sequential(problem):
    fleet = _fleet()
    eng = AmpcEngine(seed=0)
    batched = eng.solve_many(fleet, problem)
    assert len(batched) == len(fleet)
    for i, (g, res) in enumerate(zip(fleet, batched)):
        want = eng.solve(g, problem)
        assert isinstance(res, AmpcResult)
        assert np.array_equal(res.output, want.output), f"graph {i}"
        # per-graph ledger attribution: the sequential shuffle structure
        # with this graph's own DHT query split (exact for mis/matching)
        assert res.ledger["shuffles"] > 0
        if problem in ("mis", "matching"):
            assert res.ledger["shuffles"] == want.ledger["shuffles"]
            assert res.ledger["dht_queries"] == want.ledger["dht_queries"]
            assert res.stats["fixpoint_iters"] == want.stats["fixpoint_iters"]


def test_solve_many_one_vs_two_matches_sequential():
    fleet = _cycle_fleet()
    eng = AmpcEngine(seed=0)
    batched = eng.solve_many(fleet, "one-vs-two", p=1 / 8)
    for i, (g, res) in enumerate(zip(fleet, batched)):
        want = eng.solve(g, "one-vs-two", p=1 / 8)
        assert res.output == want.output, f"graph {i}"
        assert res.output == (2 if i % 2 == 0 else 1)
        assert res.stats["walk_steps"] == want.stats["walk_steps"]


def test_solve_many_weighted_riders_match_sequential():
    fleet = [g.with_random_weights(i) for i, g in enumerate(_fleet()[:6])]
    eng = AmpcEngine(seed=0)
    for problem in ("weighted-matching", "vertex-cover"):
        batched = eng.solve_many(fleet, problem)
        for g, res in zip(fleet, batched):
            want = eng.solve(g, problem)
            assert np.array_equal(res.output, want.output)


# --------------------------------------------------------------------------
# compiled-solver cache
# --------------------------------------------------------------------------
def test_cache_hit_on_second_bucket_occupant():
    fleet = _fleet()
    eng = AmpcEngine(seed=0)
    assert eng.cache_info().hits == eng.cache_info().misses == 0
    results = eng.solve_many(fleet, "mis")
    info = eng.cache_info()
    assert info.misses == len(bucketize(fleet))  # one trace per bucket
    assert info.hits > 0 and info.hit_rate > 0
    # the second occupant of every bucket rides the compiled solver
    for batch in bucketize(fleet).values():
        occupants = [results[i] for i in batch.indices]
        assert occupants[0].stats["solver_cache"]["hit"] is False
        for r in occupants[1:]:
            assert r.stats["solver_cache"]["hit"] is True
    # a second identical call is all hits, no new trace
    eng.solve_many(fleet, "mis")
    info2 = eng.cache_info()
    assert info2.misses == info.misses
    assert info2.hits == info.hits + len(fleet)
    eng.clear_cache()
    assert eng.cache_info().size == 0


def test_batch_stats_record_bucket_and_cache_key():
    fleet = _fleet()[:4]
    eng = AmpcEngine(seed=0)
    for g, res in zip(fleet, eng.solve_many(fleet, "matching")):
        assert res.stats["batch"]["bucket"] == bucket_shape(g.n, g.m)
        assert res.stats["batch"]["batch_size"] >= 1
        assert "key" in res.stats["solver_cache"]


# --------------------------------------------------------------------------
# msf: mixed dense/sparse lanes, full stats + ledger parity
# --------------------------------------------------------------------------
def _weighted_fleet():
    # varied density so the fleet exercises BOTH msf paths: even graphs are
    # sparse (truncated-Prim pipeline), odd ones dense (Borůvka shortcut)
    fleet = []
    for i in range(16):
        g = gen.erdos_renyi(24 + 5 * i, 2.0 if i % 2 == 0 else 12.0, seed=i)
        fleet.append(g.with_random_weights(seed=100 + i))
    return fleet


@pytest.mark.parametrize("backend", ["local", "routed"])
def test_solve_many_msf_matches_sequential(backend):
    fleet = _weighted_fleet()
    eng = AmpcEngine(dht_backend=backend, seed=0)
    batched = eng.solve_many(fleet, "msf")
    paths = set()
    for i, (g, res) in enumerate(zip(fleet, batched)):
        want = eng.solve(g, "msf")
        assert np.array_equal(res.output, want.output), f"graph {i}"
        assert res.stats["path"] == want.stats["path"]
        paths.add(res.stats["path"])
        if res.stats["path"] == "sparse":
            for k in ("queries", "pointer_jump_iters", "dense_phases",
                      "contracted_vertices", "budget", "n_tern",
                      "stop_cases"):
                assert res.stats[k] == want.stats[k], (i, k)
        # per-graph ledger attribution mirrors the sequential structure
        for k in ("shuffles", "dht_queries", "dht_bytes",
                  "dht_query_waves"):
            assert res.ledger[k] == want.ledger[k], (i, k)
    assert paths == {"sparse", "dense"}  # the fleet exercised both


# --------------------------------------------------------------------------
# fallback + result semantics
# --------------------------------------------------------------------------
def test_sequential_fallback_for_unbatched_problem():
    # msf/connectivity are batch-safe now; the multi-launch level algorithm
    # still falls back to one sequential solve per graph
    assert get_problem("msf").batch_fn is not None
    assert get_problem("matching-levels").batch_fn is None
    fleet = _fleet()[:2]
    eng = AmpcEngine(seed=0)
    batched = eng.solve_many(fleet, "matching-levels")
    for g, res in zip(fleet, batched):
        want = eng.solve(g, "matching-levels")
        assert np.array_equal(res.output, want.output)


def test_solve_many_validates_inputs():
    eng = AmpcEngine(seed=0)
    with pytest.raises(ValueError, match="needs edge weights"):
        eng.solve_many(_fleet()[:2], "weighted-matching")
    with pytest.raises(ValueError, match="union of cycles"):
        eng.solve_many(_fleet()[:2], "one-vs-two")


def test_raw_ledger_excluded_from_equality():
    g = gen.erdos_renyi(64, 3.0, seed=1)
    eng = AmpcEngine(seed=0)
    a, b = eng.solve_many([g, g], "mis")
    # identical graphs in one bucket: same observable fields, but the two
    # live ledgers differ (event timings) — equality must ignore raw_ledger
    assert a.raw_ledger is not b.raw_ledger
    b2 = AmpcResult(problem=b.problem, model=b.model, backend=b.backend,
                    output=a.output, stats=a.stats, ledger=a.ledger,
                    wall_time_s=a.wall_time_s, raw_ledger=b.raw_ledger)
    assert a == b2
    assert AmpcResult.__dataclass_fields__["raw_ledger"].compare is False
    # array-bearing results must compare cleanly (bool, not ValueError) ...
    assert (a == eng.solve(g, "mis")) in (True, False)
    # ... and actually detect differing outputs
    assert a != eng.solve(gen.erdos_renyi(64, 3.0, seed=2), "mis")
    assert a != "not a result"  # NotImplemented falls back to identity


def test_lookup_many_splits_queries_and_propagates_overflow():
    import jax.numpy as jnp
    from repro.ampc import LocalDht, RoutedDht
    from repro.core.rounds import RoundLedger

    vals = jnp.arange(16, dtype=jnp.int32).reshape(2, 8)
    keys = np.tile(np.arange(8, dtype=np.int32), (2, 1))
    mask = np.ones((2, 8), bool)
    mask[0, 5:] = False
    leds = [RoundLedger("a"), RoundLedger("b")]
    out = LocalDht().lookup_many(vals, keys, ledgers=leds, key_mask=mask)
    assert np.array_equal(np.asarray(out)[1], np.arange(8, 16))
    # per-graph query split by mask; exact exchange => no overflow
    assert [l.dht_queries for l in leds] == [5, 8]
    assert all(l.dht_overflows == 0 for l in leds)
    # a capacity-starved routed exchange must surface overflows per graph
    leds = [RoundLedger("a"), RoundLedger("b")]
    RoutedDht(capacity=1).lookup_many(vals, keys, ledgers=leds,
                                      key_mask=mask)
    assert all(l.dht_overflows > 0 for l in leds)


def test_routed_backend_parity_small():
    fleet = _fleet()[:3]
    eng = AmpcEngine(dht_backend="routed", seed=0)
    for problem in ("mis", "matching"):
        batched = eng.solve_many(fleet, problem)
        for g, res in zip(fleet, batched):
            want = eng.solve(g, problem)
            assert np.array_equal(res.output, want.output)
            assert res.ledger["dht_overflows"] == 0
