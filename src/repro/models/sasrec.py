"""SASRec (Kang & McAuley, arXiv:1808.09781) — sasrec config:
embed_dim=50, 2 blocks, 1 head, seq_len=50, self-attentive sequential recsys.

The item embedding table (1M x 50) is the hot path: lookups run through the
DHT dedup-gather primitive (the paper's caching optimization — repeated items
in a batch are fetched once per shard).  Scoring supports:
  * in-batch next-item training loss (sampled softmax w/ negatives)
  * serve: score given candidates
  * retrieval: one user against the full 10^6-item table (sharded matmul)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .layers import attention_xla, make_attention_mask


@dataclasses.dataclass(frozen=True)
class SASRecConfig:
    name: str = "sasrec"
    n_items: int = 1_000_000
    embed_dim: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    seq_len: int = 50
    dropout: float = 0.0
    dtype: object = jnp.float32


def init_params(cfg: SASRecConfig, key):
    keys = jax.random.split(key, 2 + 6 * cfg.n_blocks)
    d = cfg.embed_dim
    p = {
        "item_embed": jax.random.normal(keys[0], (cfg.n_items, d), cfg.dtype) * 0.02,
        "pos_embed": jax.random.normal(keys[1], (cfg.seq_len, d), cfg.dtype) * 0.02,
        "blocks": [],
    }
    s = 1.0 / np.sqrt(d)
    for i in range(cfg.n_blocks):
        k = keys[2 + 6 * i: 8 + 6 * i]
        p["blocks"].append({
            "wq": jax.random.normal(k[0], (d, d), cfg.dtype) * s,
            "wk": jax.random.normal(k[1], (d, d), cfg.dtype) * s,
            "wv": jax.random.normal(k[2], (d, d), cfg.dtype) * s,
            "wo": jax.random.normal(k[3], (d, d), cfg.dtype) * s,
            "ffn_w1": jax.random.normal(k[4], (d, d), cfg.dtype) * s,
            "ffn_w2": jax.random.normal(k[5], (d, d), cfg.dtype) * s,
            "ln1": jnp.zeros((d,), cfg.dtype),
            "ln2": jnp.zeros((d,), cfg.dtype),
        })
    return p


def _ln(x, scale, eps=1e-6):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * (1.0 + scale)


def encode(cfg: SASRecConfig, params, item_seq):
    """item_seq: (B, S) int32 -> user state (B, d) (last position repr)."""
    B, S = item_seq.shape
    d = cfg.embed_dim
    # dedup-gather through the DHT primitive (caching optimization)
    from ..core.dht import lookup
    flat = item_seq.reshape(-1)
    emb, _ = lookup(params["item_embed"], flat, dedup=True)
    x = emb.reshape(B, S, d).astype(cfg.dtype) * np.sqrt(d)
    x = x + params["pos_embed"][None, :S, :].astype(cfg.dtype)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    mask = make_attention_mask(pos, pos, None, causal=True)
    pad = item_seq > 0  # item 0 = padding
    mask = mask & pad[:, None, :]
    for blk in params["blocks"]:
        h = _ln(x, blk["ln1"])
        q = (h @ blk["wq"]).reshape(B, S, cfg.n_heads, d // cfg.n_heads)
        k = (h @ blk["wk"]).reshape(B, S, cfg.n_heads, d // cfg.n_heads)
        v = (h @ blk["wv"]).reshape(B, S, cfg.n_heads, d // cfg.n_heads)
        o = attention_xla(q, k, v, mask[:, None, None, :, :])
        x = x + o.reshape(B, S, d) @ blk["wo"]
        h2 = _ln(x, blk["ln2"])
        x = x + jax.nn.relu(h2 @ blk["ffn_w1"]) @ blk["ffn_w2"]
    x = jnp.where(pad[..., None], x, 0)
    return x  # (B, S, d) position-wise user states


def score_candidates(cfg: SASRecConfig, params, user_state, candidates):
    """user_state: (B, d); candidates: (B, C) item ids -> scores (B, C)."""
    from ..core.dht import lookup
    B, C = candidates.shape
    emb, _ = lookup(params["item_embed"], candidates.reshape(-1), dedup=True)
    emb = emb.reshape(B, C, cfg.embed_dim).astype(user_state.dtype)
    return jnp.einsum("bd,bcd->bc", user_state, emb)


def retrieval_scores(cfg: SASRecConfig, params, user_state):
    """user_state: (B, d) -> scores against the FULL item table (B, n_items).
    Lowered as a sharded matmul over the model axis."""
    return user_state @ params["item_embed"].astype(user_state.dtype).T


def loss_fn(cfg: SASRecConfig, params, item_seq, pos_items, neg_items):
    """Sequence-to-next training: BPR-style loss at every position.
    item_seq/pos_items/neg_items: (B, S)."""
    states = encode(cfg, params, item_seq)          # (B, S, d)
    from ..core.dht import lookup
    B, S = pos_items.shape
    pe, _ = lookup(params["item_embed"], pos_items.reshape(-1), dedup=True)
    ne, _ = lookup(params["item_embed"], neg_items.reshape(-1), dedup=True)
    pe = pe.reshape(B, S, -1).astype(states.dtype)
    ne = ne.reshape(B, S, -1).astype(states.dtype)
    pos_logit = (states * pe).sum(-1)
    neg_logit = (states * ne).sum(-1)
    valid = (pos_items > 0).astype(jnp.float32)
    lp = jnp.log1p(jnp.exp(-(pos_logit - neg_logit).astype(jnp.float32)))
    loss = (lp * valid).sum() / jnp.maximum(valid.sum(), 1.0)
    return loss, {"bpr": loss}
