"""sasrec: embed_dim=50, 2 blocks, 1 head, seq_len=50, 1M-item table."""
import dataclasses
from ..models.sasrec import SASRecConfig
CONFIG = SASRecConfig()
SMOKE = dataclasses.replace(SASRecConfig(), n_items=2048)
