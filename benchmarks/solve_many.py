"""Batched multi-graph serving: ``solve_many`` vs a looped ``solve``.

The serving claim behind ``AmpcEngine.solve_many``: a fleet of mixed-size
graphs padded into power-of-two shape buckets touches only a handful of
compiled programs, and one vmapped launch per bucket amortizes tracing,
dispatch, and DHT exchange across every occupant.  The looped baseline pays
one trace per *distinct graph shape* plus one launch sequence per graph.

Reported per problem: per-graph latency of the looped baseline vs the first
(``cold``, compiles per bucket) and second (``warm``, pure cache hits)
``solve_many`` pass, plus the engine's solver-cache hit rate.
"""
from __future__ import annotations

import time

import numpy as np

from repro.ampc import AmpcEngine
from repro.graph import generators as gen
from repro.graph.batching import bucketize
from repro.obs import NOOP_TRACER

from .common import fmt_table
from .registry import bench

# mixed-size fleet: sizes drawn to span a few buckets with repeats inside
# each bucket (the serving-traffic shape the cache is built for)
FLEET_SIZES = [50, 60, 100, 120, 70, 50, 90, 110, 55, 65, 95, 115, 75, 85,
               105, 125]


def _fleet(fleet_size: int):
    sizes = [FLEET_SIZES[i % len(FLEET_SIZES)] for i in range(fleet_size)]
    return [gen.erdos_renyi(n, 4.0, seed=i) for i, n in enumerate(sizes)]


def _weighted_fleet(fleet_size: int):
    # alternating density so weighted problems (msf) exercise both the
    # sparse truncated-Prim and the dense Borůvka batched sub-launches
    sizes = [FLEET_SIZES[i % len(FLEET_SIZES)] for i in range(fleet_size)]
    return [gen.erdos_renyi(n, 2.0 if i % 2 == 0 else 10.0,
                            seed=i).with_random_weights(seed=100 + i)
            for i, n in enumerate(sizes)]


def _disabled_tracer_overhead(fleet, prob, t_warm):
    """Upper-bound what the observability hooks cost a warm ``solve_many``
    pass with tracing *disabled*: count the span/event ops an enabled warm
    pass emits, multiply by the measured cost of one no-op tracer call
    (the disabled path does strictly less — most hooks are guarded behind
    a single ``tracer.enabled`` attribute check)."""
    eng = AmpcEngine(seed=0, trace=True, metrics=False)
    eng.solve_many(fleet, prob)         # compile into this engine's cache
    eng.tracer.clear()
    eng.solve_many(fleet, prob)         # warm pass, every hook live
    spans = eng.tracer.all_spans()
    ops = len(spans) + sum(len(s.events) for s in spans)
    reps = 100_000
    t0 = time.perf_counter()
    for _ in range(reps):
        with NOOP_TRACER.span("x"):
            pass
    per_op = (time.perf_counter() - t0) / reps
    return ops, per_op, ops * per_op / max(t_warm, 1e-9)


@bench("solve_many",
       quick_kwargs={"problems": ["mis", "matching", "msf", "connectivity"],
                     "fleet_size": 8},
       summary="solve_many vs looped solve(): per-graph latency on a "
               "mixed-size fleet")
def run(problems=None, fleet_size: int = 16):
    from repro.ampc.registry import get as get_problem

    problems = problems or ["mis", "matching", "connectivity", "msf"]
    plain_fleet = _fleet(fleet_size)
    weighted = _weighted_fleet(fleet_size)
    buckets = bucketize(plain_fleet)
    print(f"fleet: {len(plain_fleet)} graphs in {len(buckets)} shape "
          f"buckets {sorted(buckets)}")
    rows = []
    speedups = {}
    warm_times = {}
    for prob in problems:
        fleet = weighted if get_problem(prob).needs_weights else plain_fleet
        eng = AmpcEngine(seed=0)   # fresh engine: cold solver cache
        t0 = time.perf_counter()
        seq = [eng.solve(g, prob) for g in fleet]
        t_loop = time.perf_counter() - t0
        t0 = time.perf_counter()
        cold = eng.solve_many(fleet, prob)
        t_cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        warm = eng.solve_many(fleet, prob)
        t_warm = time.perf_counter() - t0
        for s, c, w in zip(seq, cold, warm):
            assert np.array_equal(s.output, c.output), "batched != sequential"
            assert np.array_equal(s.output, w.output)
        info = eng.cache_info()
        n = len(fleet)
        speedups[prob] = t_loop / max(t_warm, 1e-9)
        warm_times[prob] = t_warm
        rows.append([prob, n,
                     f"{1e3 * t_loop / n:.1f}", f"{1e3 * t_cold / n:.1f}",
                     f"{1e3 * t_warm / n:.1f}",
                     f"{t_loop / max(t_cold, 1e-9):.1f}x",
                     f"{t_loop / max(t_warm, 1e-9):.1f}x",
                     f"{info.hit_rate:.2f}"])
    out = fmt_table(["problem", "graphs", "loop ms/g", "batched cold ms/g",
                     "batched warm ms/g", "speedup cold", "speedup warm",
                     "cache hit-rate"], rows)
    print(out)
    print("\nper-graph latency: one vmapped launch per shape bucket vs one "
          "launch sequence per graph; warm = compiled-solver cache hits only")
    probe = problems[0]
    probe_fleet = (weighted if get_problem(probe).needs_weights
                   else plain_fleet)
    ops, per_op, frac = _disabled_tracer_overhead(
        probe_fleet, probe, warm_times[probe])
    print(f"\ndisabled-tracer overhead ({probe} warm pass): {ops} hook ops "
          f"x {per_op * 1e9:.0f}ns no-op = {100 * frac:.3f}% of "
          f"{1e3 * warm_times[probe]:.1f}ms")
    assert frac < 0.02, \
        f"disabled-tracer overhead {100 * frac:.2f}% exceeds the 2% budget"
    return {"rows": rows, "markdown": out, "speedups": speedups,
            "tracer_overhead_pct": 100 * frac,
            "buckets": {str(k): len(v) for k, v in buckets.items()}}


@bench("async_serving",
       quick_kwargs={"problems": ["mis"], "fleet_size": 8, "repeats": 2},
       summary="submit()-based async serving vs a blocking solve loop, "
               "plus warm GraphSession snapshot reuse")
def run_async(problems=None, fleet_size: int = 16, repeats: int = 3,
              max_workers: int = 4):
    """Throughput of ``submit_many`` + gather vs the blocking loop.

    On a single local device the launch lock serializes the numerical
    work, so the async win is bounded by the host-side share of each
    solve — the benchmark reports the measured ratio rather than
    asserting a speedup, and verifies output parity future-by-future.
    Also reports the per-solve saving of a warm ``GraphSession``
    (snapshot reuse: 1 shuffle instead of 2).
    """
    problems = problems or ["mis", "matching"]
    fleet = _fleet(fleet_size)
    rows = []
    ratios = {}
    for prob in problems:
        with AmpcEngine(seed=0, max_workers=max_workers) as eng:
            seq = [eng.solve(g, prob) for g in fleet]  # also warms compiles
            t_loop = t_async = 0.0
            for _ in range(repeats):
                t0 = time.perf_counter()
                loop_res = [eng.solve(g, prob) for g in fleet]
                t_loop += time.perf_counter() - t0
                t0 = time.perf_counter()
                futs = eng.submit_many(fleet, prob)
                async_res = [f.result(timeout=600) for f in futs]
                t_async += time.perf_counter() - t0
            for s, l, a in zip(seq, loop_res, async_res):
                assert np.array_equal(s.output, l.output)
                assert np.array_equal(s.output, a.output), \
                    "async != sequential"
            n = repeats * len(fleet)
            ratios[prob] = t_loop / max(t_async, 1e-9)
            rows.append([prob, n, f"{1e3 * t_loop / n:.1f}",
                         f"{1e3 * t_async / n:.1f}",
                         f"{ratios[prob]:.2f}x"])
    out = fmt_table(["problem", "solves", "blocking ms/solve",
                     "async ms/solve", "async speedup"], rows)
    print(out)
    print("\nsingle-device: device launches serialize behind the engine "
          "launch lock; the async win is the overlapped host-side work")
    # warm-session snapshot reuse on one graph
    g = fleet[-1]
    with AmpcEngine(seed=0) as eng:
        sess = eng.session(g)
        cold = sess.solve("mis")
        sess.solve("matching")             # trace the snapshot-fed variant
        eng.solve(g, "matching")           # ... and the plain variant
        t0 = time.perf_counter()
        warm = sess.solve("matching")
        t_warm_sess = time.perf_counter() - t0
        t0 = time.perf_counter()
        plain = eng.solve(g, "matching")
        t_plain = time.perf_counter() - t0
    assert np.array_equal(warm.output, plain.output)
    assert warm.stats["snapshot"]["hit"] and warm.ledger["shuffles"] == 1
    print(f"\nGraphSession warm matching: {1e3 * t_warm_sess:.1f}ms "
          f"({warm.ledger['shuffles']} shuffle) vs plain "
          f"{1e3 * t_plain:.1f}ms ({plain.ledger['shuffles']} shuffles); "
          f"cold snapshot build paid once ({cold.ledger['shuffles']} "
          "shuffles)")
    return {"rows": rows, "markdown": out, "async_speedups": ratios,
            "session_warm_shuffles": warm.ledger["shuffles"]}


if __name__ == "__main__":
    run()
    run_async()
