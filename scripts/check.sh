#!/usr/bin/env bash
# Repo health check: lint (when ruff is available) + the tier-1 test suite.
#
#   scripts/check.sh            # lint + full tier-1 pytest
#   scripts/check.sh --fast     # lint + the observability/docs/engine subset
#
# ruff is optional (the dev container does not ship it); when absent the
# lint step is skipped with a notice instead of failing the check.
#
# The pytest run is wrapped in coreutils timeout(1) so a wedged worker
# pool (async-engine deadlock) fails the check loudly instead of hanging
# CI forever.  Override the budget with CHECK_TIMEOUT_SECS.
set -euo pipefail
cd "$(dirname "$0")/.."

TIMEOUT_SECS="${CHECK_TIMEOUT_SECS:-2400}"
run_pytest() {
    if command -v timeout >/dev/null 2>&1; then
        # -k 30: SIGKILL stragglers 30s after the initial SIGTERM
        timeout -k 30 "$TIMEOUT_SECS" python -m pytest "$@" || {
            rc=$?
            if [[ $rc == 124 || $rc == 137 ]]; then
                echo "== pytest exceeded ${TIMEOUT_SECS}s — possible" \
                     "pool deadlock (see tests/test_async_engine.py)" >&2
            fi
            return $rc
        }
    else
        python -m pytest "$@"
    fi
}

if command -v ruff >/dev/null 2>&1; then
    echo "== ruff check"
    ruff check src/repro benchmarks tests
else
    echo "== ruff not installed; skipping lint"
fi

echo "== host-sync lint (hot-path modules must stay dispatch-only)"
python scripts/lint_host_sync.py

echo "== tier-1 pytest"
export PYTHONPATH=src
if [[ "${1:-}" == "--fast" ]]; then
    run_pytest -x -q tests/test_obs.py tests/test_docs.py \
        tests/test_engine.py tests/test_smoke_benchmarks.py \
        tests/test_async_engine.py
    exit $?
fi
run_pytest -x -q
