"""§Perf hillclimb 3 (paper-representative): GNN message passing as an AMPC
DHT query wave.

Baseline: ``out = segment_sum(take(h, senders), receivers)`` with node/edge
arrays sharded over the flat mesh — XLA emits global gathers/scatters whose
wire bytes scale with E (every edge crosses the fabric).

DHT variant (the paper's technique applied as an optimization): edges are
placed receiver-aligned (each device owns the edges pointing at its node
range — a preprocessing shuffle, exactly the paper's "SortGraph" round), the
sender-feature fetch becomes a dedup'd routed lookup (core.dht.routed_lookup
= the caching optimization of Section 5.3 + all_to_all), and the
segment-sum is device-local.  Wire bytes scale with the number of *distinct*
remote senders per device — on power-law graphs a 2-10x reduction (the same
hub-caching effect Fig 4 measures).

Two measurements:
  A) static collective bytes on the production mesh (dry-run lower+compile)
     for both variants at ogb_products scale (capacity sized by the
     empirically measured dedup factor);
  B) empirical dedup factor + overflow safety on a real RMAT graph executed
     on an 8-device CPU mesh.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np

from .registry import bench

MEASURE_B = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.graph import generators as gen
    from repro.core import dht

    g = gen.rmat(14, 32.0, seed=0)          # power-law, avg deg ~32
    s, r, _, _ = g.symmetric()
    order = np.argsort(r)                    # receiver-aligned placement
    s, r = s[order], r[order]
    P_dev = 8
    n = ((g.n + P_dev - 1) // P_dev) * P_dev
    E = (len(s) // P_dev) * P_dev
    s, r = s[:E], r[:E]
    mesh = jax.make_mesh((P_dev,), ("x",))
    vals = jax.device_put(jnp.zeros((n, 8), jnp.float32),
                          NamedSharding(mesh, P("x", None)))
    keys = jax.device_put(jnp.asarray(s), NamedSharding(mesh, P("x")))
    # per-device dedup factor: edges per device / distinct senders per device
    per = E // P_dev
    facs, remote = [], []
    for d in range(P_dev):
        sd = s[d*per:(d+1)*per]
        facs.append(per / max(len(np.unique(sd)), 1))
        owner = np.unique(sd) // (n // P_dev)
        remote.append((owner != d).mean())
    out, n_unique, overflow = dht.routed_lookup(vals, keys, mesh, "x")
    print(f"DEDUP_FACTOR {np.mean(facs):.2f}")
    print(f"REMOTE_FRAC {np.mean(remote):.2f}")
    print(f"OVERFLOW {int(overflow)}")
""")


@bench("gnn_dht_hillclimb",
       summary="§Perf hillclimb: GNN message passing as a DHT query wave")
def run():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    rb = subprocess.run([sys.executable, "-c", MEASURE_B], env=env,
                        capture_output=True, text=True, timeout=900)
    print("-- measurement B (8-device execution, RMAT deg~32) --")
    print(rb.stdout.strip())
    assert rb.returncode == 0, rb.stderr[-1500:]
    dedup = float(rb.stdout.split("DEDUP_FACTOR")[1].split()[0])

    # A) static analysis at ogb_products scale. GNN jobs view the fabric as
    # one flat 512-device axis (pure DP over segments), so the router uses a
    # single named axis.
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        import numpy as np, jax, jax.numpy as jnp, functools
        from jax.sharding import NamedSharding, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.launch.hlo import analyze_hlo
        from repro.core import dht

        mesh = jax.make_mesh((512,), ("nodes",))
        chips = 512
        N, E, C = 2449408, 123718656, 128
        S = jax.ShapeDtypeStruct
        flat1 = NamedSharding(mesh, P("nodes"))
        flat2 = NamedSharding(mesh, P("nodes", None))

        def baseline(h, senders, receivers):
            msg = jnp.take(h, senders, axis=0)
            return jax.ops.segment_sum(msg, receivers, num_segments=N)

        low = jax.jit(baseline, in_shardings=(flat2, flat1, flat1),
                      out_shardings=flat2).lower(
            S((N, C), jnp.float32), S((E,), jnp.int32), S((E,), jnp.int32))
        a = analyze_hlo(low.compile().as_text())
        print(f"BASELINE_WIRE {{a.collectives.wire_bytes:.4g}}")

        # DHT variant: receiver-aligned edges; per-destination capacity
        # sized by the measured dedup factor ({dedup:.2f}x, 1.5x margin)
        E_loc = E // chips
        uniq_est = int(E_loc / {dedup:.2f} * 1.5)
        cap_dest = max(uniq_est // chips * 6, 64)   # 6x skew headroom

        def dht_variant(h, senders, receivers):
            fetched, n_unique, overflow = dht.routed_lookup(
                h, senders, mesh, "nodes", capacity=cap_dest)
            # receiver-aligned edges => the segment-sum is device-local
            def local_sum(msg_l, r_l):
                base = r_l.min()
                return jax.ops.segment_sum(msg_l, r_l - base,
                                           num_segments=N // chips)
            out = shard_map(local_sum, mesh=mesh,
                            in_specs=(P("nodes", None), P("nodes")),
                            out_specs=P("nodes", None),
                            check_rep=False)(fetched, receivers)
            return out, overflow

        low2 = jax.jit(dht_variant, in_shardings=(flat2, flat1, flat1),
                       out_shardings=(flat2, None)).lower(
            S((N, C), jnp.float32), S((E,), jnp.int32), S((E,), jnp.int32))
        a2 = analyze_hlo(low2.compile().as_text())
        print(f"DHT_WIRE {{a2.collectives.wire_bytes:.4g}}")
        print(f"CAP {{cap_dest}} E_LOC {{E_loc}}")
    """)
    ra = subprocess.run([sys.executable, "-c", code], env=env,
                        capture_output=True, text=True, timeout=1800)
    print("\n-- measurement A (static, production mesh, ogb_products scale) --")
    print(ra.stdout.strip())
    if ra.returncode != 0:
        print(ra.stderr[-1500:])
        return {"error": "static analysis failed", "dedup": dedup}
    base = float(ra.stdout.split("BASELINE_WIRE")[1].split()[0])
    dhtw = float(ra.stdout.split("DHT_WIRE")[1].split()[0])
    print(f"\nwire bytes/device: baseline {base:.3g} -> dht {dhtw:.3g} "
          f"({base/max(dhtw,1):.1f}x reduction; measured dedup {dedup:.2f}x)")
    return {"baseline_wire": base, "dht_wire": dhtw, "dedup": dedup}


if __name__ == "__main__":
    run()
