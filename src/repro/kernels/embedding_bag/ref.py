"""Pure-jnp oracle: EmbeddingBag (sum mode) — the recsys hot path.

JAX has no native EmbeddingBag; the reference is gather + masked sum, and the
Pallas kernel fuses the gather loop with the accumulation (no (B, L, D)
intermediate)."""
from __future__ import annotations

import jax.numpy as jnp


def embedding_bag_ref(table, ids):
    """table: (V, D); ids: (B, L) int32, 0 = padding row (excluded).
    Returns (B, D) sums."""
    emb = table[jnp.clip(ids, 0, table.shape[0] - 1)]
    emb = jnp.where((ids > 0)[..., None], emb, 0)
    return emb.sum(axis=1)
