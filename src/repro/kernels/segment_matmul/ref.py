"""Pure-jnp oracle: padded-neighbor aggregation + weight transform.

out[n] = (sum_k x[nbr[n, k]]) @ W   with nbr == -1 entries masked.
This is the GNN message-passing hot loop in padded-CSR form (the form the
AMPC ternarized graphs and sampled blocks use).
"""
from __future__ import annotations

import jax.numpy as jnp


def segment_matmul_ref(x, nbr, w):
    """x: (N, D); nbr: (N, K) int32 (-1 pad); w: (D, F) -> (N, F)."""
    safe = jnp.clip(nbr, 0, x.shape[0] - 1)
    gathered = x[safe]                                   # (N, K, D)
    gathered = jnp.where((nbr >= 0)[..., None], gathered, 0)
    agg = gathered.sum(axis=1)                           # (N, D)
    return agg @ w
