"""Hypothesis property tests: system invariants on random graphs.

Kept small (shape changes recompile the jitted fixpoints) but fully random —
these catch structural edge cases the fixed-family tests miss (self-loop
handling, isolated vertices, disconnected graphs, duplicate edges).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.graph.coo import UGraph
from repro.core import matching as mm, mis, msf, oracle


def _random_graph(draw):
    n = draw(st.integers(5, 40))
    m = draw(st.integers(0, 80))
    rng = np.random.default_rng(draw(st.integers(0, 10_000)))
    e = rng.integers(0, n, (m, 2)).astype(np.int32)
    return UGraph(n, e).dedup()


@settings(max_examples=12, deadline=None)
@given(st.data())
def test_msf_weight_equals_kruskal(data):
    g = _random_graph(data.draw)
    if g.m == 0:
        return
    g = g.with_random_weights(data.draw(st.integers(0, 100)))
    mo, wo = oracle.kruskal_msf(g)
    ma, _ = msf.msf_ampc(g, seed=0, skip_ternarize_if_dense=False)
    assert np.array_equal(mo, ma)


@settings(max_examples=12, deadline=None)
@given(st.data())
def test_mis_is_lfmis_and_maximal(data):
    g = _random_graph(data.draw)
    got, _ = mis.mis_ampc(g, seed=3)
    rng = np.random.default_rng(3)
    want = oracle.greedy_mis(g, rng.permutation(g.n).astype(np.float32))
    assert np.array_equal(got, want)
    # independence
    for u, v in g.edges:
        assert not (got[u] and got[v])


@settings(max_examples=12, deadline=None)
@given(st.data())
def test_mm_is_lfmm_and_maximal(data):
    g = _random_graph(data.draw)
    if g.m == 0:
        return
    got, stats = mm.mm_ampc(g, seed=5)
    want = oracle.greedy_mm(g, stats["erank"])
    assert np.array_equal(got, want)
    assert oracle.is_maximal_matching(g, got)


@settings(max_examples=10, deadline=None)
@given(st.data())
def test_corollary_4_1_matching_approximation(data):
    """Corollary 4.1: random-greedy MM is a 2-approx of maximum matching
    (we verify |MM| >= nu(G)/2 via the LP bound |MM| >= |M*|/2 using the
    oracle's greedy as M and a brute-force max matching on tiny graphs)."""
    n = data.draw(st.integers(4, 12))
    m = data.draw(st.integers(2, 20))
    rng = np.random.default_rng(data.draw(st.integers(0, 1000)))
    e = rng.integers(0, n, (m, 2)).astype(np.int32)
    g = UGraph(n, e).dedup()
    if g.m == 0:
        return
    got, _ = mm.mm_ampc(g, seed=1)
    # brute force maximum matching via bitmask DP over edges (tiny sizes)
    best = 0
    edges = g.edges.tolist()
    import itertools
    for k in range(min(len(edges), n // 2), 0, -1):
        found = False
        for combo in itertools.combinations(range(len(edges)), k):
            used = set()
            ok = True
            for ei in combo:
                u, v = edges[ei]
                if u in used or v in used:
                    ok = False
                    break
                used.add(u); used.add(v)
            if ok:
                found = True
                break
        if found:
            best = k
            break
    assert int(got.sum()) * 2 >= best
