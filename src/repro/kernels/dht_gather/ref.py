"""Pure-jnp oracle: gather rows for a SORTED key batch (the DHT lookup)."""
from __future__ import annotations

import jax.numpy as jnp


def dht_gather_ref(table, sorted_keys):
    """table: (V, D); sorted_keys: (Q,) int32 ascending, -1 = padding.
    Returns (Q, D); padding rows are zeros."""
    safe = jnp.clip(sorted_keys, 0, table.shape[0] - 1)
    out = table[safe]
    return jnp.where((sorted_keys >= 0)[:, None], out, 0)
