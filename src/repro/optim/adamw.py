"""AdamW with fp32 master weights, cosine schedule, global-norm clipping.

Optimizer state is a pytree congruent with params (ZeRO sharding falls out of
the same sharding rules applied to `m`/`v`/`master`).  Pure functions — no
optax dependency.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    state_dtype: str = "float32"   # "bfloat16" halves m/v HBM (§Perf note)


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def init_state(params, cfg: "AdamWConfig" = None) -> Dict[str, Any]:
    dt = jnp.bfloat16 if (cfg is not None and
                          cfg.state_dtype == "bfloat16") else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def apply_updates(cfg: AdamWConfig, params, grads, state
                  ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    b1, b2 = cfg.b1, cfg.b2

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v2 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mh = m2 / (1 - b1 ** step.astype(jnp.float32))
        vh = v2 / (1 - b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), \
            m2.astype(m.dtype), v2.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"lr": lr, "grad_norm": gnorm}
