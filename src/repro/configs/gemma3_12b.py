"""gemma3-12b: 48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144,
5:1 local:global sliding window, 128k context."""
from .lm_archs import GEMMA3_12B as CONFIG, smoke
SMOKE = smoke(CONFIG)
