"""Snapshot-aware ``msf`` / ``connectivity`` / ``one-vs-two`` sessions.

The tentpole contract for the richer ``GraphSnapshot`` KV layout: warm
session solves of every Table-3 core problem skip both the write shuffle
and the per-solve ternarize rebuild (1 materialized round instead of 2)
while staying bit-identical to plain ``engine.solve`` — plus the cache /
alias edge-case regressions that ride along.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.ampc import AmpcEngine, registry
from repro.ampc.session import SNAPSHOT_PROBLEMS
from repro.graph import generators as gen


def _sparse_weighted(seed=1):
    return gen.erdos_renyi(60, 2.0, seed=seed).with_random_weights(
        seed=seed + 100)


@pytest.mark.parametrize("backend", ["local", "routed"])
@pytest.mark.parametrize("problem", ["msf", "connectivity"])
def test_warm_session_one_shuffle_bit_identical(backend, problem):
    g = _sparse_weighted()
    eng = AmpcEngine(dht_backend=backend, seed=0)
    want = eng.solve(g, problem)
    sess = eng.session(g)
    cold = sess.solve(problem)
    warm = sess.solve(problem)
    assert np.array_equal(want.output, cold.output)
    assert np.array_equal(want.output, warm.output)
    assert cold.stats["snapshot"]["hit"] is False
    assert warm.stats["snapshot"]["hit"] is True
    assert cold.ledger["shuffles"] == 2 and warm.ledger["shuffles"] == 1


def test_warm_session_dense_msf():
    g = gen.erdos_renyi(40, 14.0, seed=2).with_random_weights(seed=5)
    eng = AmpcEngine(seed=0)
    want = eng.solve(g, "msf")
    assert want.stats["path"] == "dense"
    sess = eng.session(g)
    cold, warm = sess.solve("msf"), sess.solve("msf")
    assert np.array_equal(want.output, cold.output)
    assert np.array_equal(want.output, warm.output)
    assert warm.stats["snapshot"]["hit"] and warm.ledger["shuffles"] == 1


def test_msf_and_cc_views_are_distinct():
    # msf and connectivity ternarize differently (real weights vs unit
    # weights + first-slot map): one session carries both views, each built
    # once, and invalidate() drops them together by key prefix
    g = _sparse_weighted(3)
    eng = AmpcEngine(seed=0)
    sess = eng.session(g)
    m1 = sess.solve("msf")
    c1 = sess.solve("connectivity")
    assert c1.stats["snapshot"]["hit"] is False  # its own view, own build
    m2 = sess.solve("msf")
    c2 = sess.solve("connectivity")
    assert m2.stats["snapshot"]["hit"] and c2.stats["snapshot"]["hit"]
    assert np.array_equal(m1.output, m2.output)
    assert np.array_equal(c1.output, c2.output)
    assert sess.invalidate() == 2
    assert sess.invalidate() == 0


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10_000))
def test_property_session_msf_cc_bit_exact(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(8, 48))
    g = gen.erdos_renyi(n, float(rng.uniform(1.0, 6.0)), seed=seed)
    if g.m == 0:
        g = gen.path(n)
    g = g.with_random_weights(seed=seed + 1)
    eng = AmpcEngine(seed=seed % 7)
    sess = eng.session(g)
    for problem in ("msf", "connectivity"):
        want = AmpcEngine(seed=seed % 7).solve(g, problem)
        cold = sess.solve(problem)
        warm = sess.solve(problem)
        assert np.array_equal(want.output, cold.output)
        assert np.array_equal(want.output, warm.output)


# --------------------------------------------------------------------------
# satellite regressions: cache kinds, invalidate idempotency, alias support
# --------------------------------------------------------------------------
def test_cache_info_unknown_kind_raises():
    eng = AmpcEngine(seed=0)
    with pytest.raises(ValueError, match="solver"):
        eng.cache_info(kind="bogus")
    with pytest.raises(ValueError, match="snapshot"):
        eng.cache_info(kind="")


def test_invalidate_idempotent_after_clear_cache():
    g = _sparse_weighted(4)
    eng = AmpcEngine(seed=0)
    sess = eng.session(g)
    sess.solve("msf")
    eng.clear_cache()
    assert eng.cache_info(kind="snapshot").size == 0
    assert sess.invalidate() == 0  # nothing left to evict, no miscount
    assert sess.invalidate() == 0
    res = sess.solve("msf")  # rebuilds cleanly after the clear
    assert res.stats["snapshot"]["hit"] is False


def test_alias_resolution_for_snapshot_support():
    eng = AmpcEngine(seed=0)
    sess = eng.session(gen.erdos_renyi(30, 3.0, seed=0))
    # aliases resolve through the registry: canonical-name membership only
    for name in ("cc", "connectivity", "mm", "1v2c", "ampc-mis", "mwm"):
        assert sess._supported(name), name
    # -mpc baselines and multi-launch variants must not claim support
    for name in ("msf-mpc", "connectivity-mpc", "matching-mpc", "mis-mpc",
                 "one-vs-two-mpc", "matching-levels", "msf-kkt",
                 "matching-vertex-process"):
        assert not sess._supported(name), name


def test_snapshot_problems_are_registered_canonical_names():
    names = {s.name for s in registry.specs()}
    assert SNAPSHOT_PROBLEMS <= names
