"""Connected components in O(1) adaptive rounds (paper Theorem 1).

The paper obtains connectivity from MSF: compute any spanning forest, then
apply forest connectivity (Proposition 3.2).  ``cc_ampc`` runs the same
5-shuffle pipeline as ``msf_ampc`` on unit weights (edge-id tie-broken) and
composes the two contraction maps into per-vertex component labels.

``cc_mpc_hash_to_min`` is the MPC baseline: min-label propagation with one
materialized launch per phase (the CC-LocalContraction stand-in used for the
1-vs-2-cycle comparison in Section 5.6).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.coo import UGraph
from .rounds import RoundLedger


def _canonicalize(labels: np.ndarray) -> np.ndarray:
    """Relabel components by their minimum vertex id (oracle convention).
    Label values may live in any id space (e.g. ternarized vertices)."""
    n = labels.shape[0]
    _, inv = np.unique(labels, return_inverse=True)
    rep = np.full(inv.max() + 1, n, np.int64)
    np.minimum.at(rep, inv, np.arange(n))
    return rep[inv]


# --------------------------------------------------------------------------
# MPC baseline: min-label propagation (hash-to-min), one launch per phase
# --------------------------------------------------------------------------
@jax.jit
def _h2m_phase(u, v, labels):
    lu, lv = labels[u], labels[v]
    mn = jnp.minimum(lu, lv)
    n = labels.shape[0]
    new = labels
    new = new.at[u].min(mn)
    new = new.at[v].min(mn)
    new = new.at[lu].min(mn)   # hash-to-min: also hook the current root
    new = new.at[lv].min(mn)
    new = jnp.take(new, new)   # shortcut
    changed = jnp.any(new != labels)
    return new, changed


def cc_ampc(g: UGraph, epsilon: float = 0.5, seed: int = 0,
            ledger: Optional[RoundLedger] = None) -> Tuple[np.ndarray, dict]:
    """Deprecated shim over repro.ampc.solvers.cc_ampc."""
    from ..ampc import solvers
    from ..ampc.deprecation import warn_once
    warn_once("repro.core.connectivity.cc_ampc",
              'AmpcEngine().solve(g, "connectivity")')
    return solvers.cc_ampc(g, epsilon=epsilon, seed=seed, ledger=ledger)


def cc_mpc_hash_to_min(g: UGraph, ledger: Optional[RoundLedger] = None,
                       max_phases: int = 200) -> Tuple[np.ndarray, dict]:
    """Deprecated shim over repro.ampc.solvers.cc_mpc_hash_to_min."""
    from ..ampc import solvers
    from ..ampc.deprecation import warn_once
    warn_once("repro.core.connectivity.cc_mpc_hash_to_min",
              'AmpcEngine().solve(g, "connectivity-mpc")')
    return solvers.cc_mpc_hash_to_min(g, ledger=ledger,
                                      max_phases=max_phases)
