"""AMPC paper reproduction: parallel graph algorithms in constant adaptive
rounds, on the JAX/Pallas stack.

Top-level packages: ``repro.ampc`` (the engine API — start at
``repro.ampc.AmpcEngine``), ``repro.core`` (jitted algorithm primitives and
ledger accounting), ``repro.graph`` (containers, generators, batching).
See the repository README for the full map.
"""
