"""Architecture registry: --arch <id> resolves here.

Each entry: family ("lm" | "gnn" | "recsys"), full config, smoke config,
the shape set it pairs with, and notes (e.g. skipped shapes).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

from . import lm_archs
from .shapes import GNN_SHAPES, LM_SHAPES, REC_SHAPES, ShapeSpec
from ..models.gnn.gcn import GCNConfig
from ..models.gnn.gin import GINConfig
from ..models.gnn.mace import MACEConfig
from ..models.gnn.schnet import SchNetConfig
from ..models.sasrec import SASRecConfig


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    arch_id: str
    family: str
    config: Any
    smoke_config: Any
    shapes: Dict[str, ShapeSpec]
    skip_shapes: Dict[str, str] = dataclasses.field(default_factory=dict)


def _gnn_smoke(cfg):
    import dataclasses as dc
    kw = {}
    if hasattr(cfg, "d_hidden"):
        kw["d_hidden"] = min(cfg.d_hidden, 16)
    if hasattr(cfg, "n_rbf"):
        kw["n_rbf"] = min(cfg.n_rbf, 8)
    return dc.replace(cfg, **kw)


REGISTRY: Dict[str, ArchEntry] = {}


def _reg(entry: ArchEntry):
    REGISTRY[entry.arch_id] = entry


_full_attn_skip = ("long_500k needs sub-quadratic attention; this arch is "
                   "pure full attention as configured (DESIGN.md §4)")

_reg(ArchEntry("gemma3-12b", "lm", lm_archs.GEMMA3_12B,
               lm_archs.smoke(lm_archs.GEMMA3_12B), LM_SHAPES))
_reg(ArchEntry("qwen2.5-32b", "lm", lm_archs.QWEN2_5_32B,
               lm_archs.smoke(lm_archs.QWEN2_5_32B), LM_SHAPES,
               {"long_500k": _full_attn_skip}))
_reg(ArchEntry("qwen3-4b", "lm", lm_archs.QWEN3_4B,
               lm_archs.smoke(lm_archs.QWEN3_4B), LM_SHAPES,
               {"long_500k": _full_attn_skip}))
_reg(ArchEntry("llama4-scout-17b-a16e", "lm", lm_archs.LLAMA4_SCOUT,
               lm_archs.smoke(lm_archs.LLAMA4_SCOUT), LM_SHAPES,
               {"long_500k": _full_attn_skip + "; llama4 chunked attention "
                "not reproduced"}))
_reg(ArchEntry("mixtral-8x22b", "lm", lm_archs.MIXTRAL_8X22B,
               lm_archs.smoke(lm_archs.MIXTRAL_8X22B), LM_SHAPES))

_reg(ArchEntry("mace", "gnn",
               MACEConfig(),
               _gnn_smoke(MACEConfig(d_hidden=16, n_rbf=4)),
               GNN_SHAPES))
_reg(ArchEntry("gin-tu", "gnn", GINConfig(),
               _gnn_smoke(GINConfig(d_hidden=16)), GNN_SHAPES))
_reg(ArchEntry("schnet", "gnn", SchNetConfig(),
               _gnn_smoke(SchNetConfig(d_hidden=16, n_rbf=8)), GNN_SHAPES))
_reg(ArchEntry("gcn-cora", "gnn", GCNConfig(),
               _gnn_smoke(GCNConfig()), GNN_SHAPES))

_reg(ArchEntry("sasrec", "recsys", SASRecConfig(),
               dataclasses.replace(SASRecConfig(), n_items=2048),
               REC_SHAPES))


def get(arch_id: str) -> ArchEntry:
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch '{arch_id}'; known: {sorted(REGISTRY)}")
    return REGISTRY[arch_id]


def all_cells():
    """Yield (arch_id, shape_name, skipped_reason|None) for all 40 cells."""
    for aid, entry in REGISTRY.items():
        for sname in entry.shapes:
            yield aid, sname, entry.skip_shapes.get(sname)
