"""Assigned input-shape sets per architecture family (40 cells total).

LM shapes: seq_len x global_batch; decode_*/long_* lower ``serve_step``
(1 new token against a KV cache), not ``train_step``.
GNN shapes: graph-scale regimes.  RecSys: batch regimes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str                 # train | prefill | decode | gnn_full | gnn_sampled
    #                         | gnn_batched | rec_train | rec_serve | rec_retrieval
    seq_len: int = 0
    global_batch: int = 0
    # gnn
    n_nodes: int = 0
    n_edges: int = 0
    d_feat: int = 0
    batch_nodes: int = 0
    fanout: tuple = ()
    n_graphs: int = 0
    # recsys
    n_candidates: int = 0


LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", seq_len=4096, global_batch=256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", seq_len=32768,
                             global_batch=32),
    "decode_32k": ShapeSpec("decode_32k", "decode", seq_len=32768,
                            global_batch=128),
    "long_500k": ShapeSpec("long_500k", "decode", seq_len=524288,
                           global_batch=1),
}

GNN_SHAPES = {
    # Cora-scale full batch
    "full_graph_sm": ShapeSpec("full_graph_sm", "gnn_full", n_nodes=2708,
                               n_edges=10556, d_feat=1433),
    # Reddit-scale sampled minibatch (fanout 15,10 from 1024 seeds)
    "minibatch_lg": ShapeSpec("minibatch_lg", "gnn_sampled", n_nodes=232965,
                              n_edges=114615892, d_feat=602,
                              batch_nodes=1024, fanout=(15, 10)),
    # ogbn-products full batch
    "ogb_products": ShapeSpec("ogb_products", "gnn_full", n_nodes=2449029,
                              n_edges=61859140, d_feat=100),
    # batched small molecules
    "molecule": ShapeSpec("molecule", "gnn_batched", n_nodes=30, n_edges=64,
                          n_graphs=128),
}

REC_SHAPES = {
    "train_batch": ShapeSpec("train_batch", "rec_train", global_batch=65536),
    "serve_p99": ShapeSpec("serve_p99", "rec_serve", global_batch=512),
    "serve_bulk": ShapeSpec("serve_bulk", "rec_serve", global_batch=262144),
    "retrieval_cand": ShapeSpec("retrieval_cand", "rec_retrieval",
                                global_batch=1, n_candidates=1_000_000),
}


def sampled_block_sizes(spec: ShapeSpec):
    """Layer-wise sampled-subgraph sizes for minibatch_lg: node/edge counts of
    the padded 2-hop block (seeds=1024, fanout 15 then 10)."""
    seeds = spec.batch_nodes
    l1 = seeds * spec.fanout[0]
    l2 = l1 * spec.fanout[1]
    n_nodes = seeds + l1 + l2
    n_edges = l1 + l2
    return n_nodes, n_edges
