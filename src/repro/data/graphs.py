"""Graph dataset builders: padded GraphBatch construction + neighbor sampler.

Provides stand-ins for the assigned GNN shape regimes:
  * cora_like       — full_graph_sm (node classification)
  * products_like   — ogb_products (full-batch large; scaled down for tests)
  * molecules       — batched small radius graphs with positions/species
  * NeighborSampler — layer-wise fanout sampling (minibatch_lg), real CSR
                      sampling in numpy (this IS the data pipeline hot path)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np
import jax.numpy as jnp

from ..graph import generators as gen
from ..graph.coo import UGraph
from ..models.gnn.common import GraphBatch


def _to_batch(g: UGraph, node_feat=None, positions=None, species=None,
              labels=None, graph_ids=None, n_graphs=1,
              pad_nodes: Optional[int] = None, pad_edges: Optional[int] = None):
    s, r, _, _ = g.symmetric()
    n, e = g.n, len(s)
    pn = pad_nodes or n
    pe = pad_edges or e
    assert pn >= n and pe >= e
    senders = np.full(pe, pn - 1, np.int32); senders[:e] = s
    receivers = np.full(pe, pn - 1, np.int32); receivers[:e] = r
    edge_mask = np.zeros(pe, bool); edge_mask[:e] = True
    node_mask = np.zeros(pn, bool); node_mask[:n] = True

    def pad2(x, fill=0.0):
        if x is None:
            return None
        out = np.full((pn,) + x.shape[1:], fill, x.dtype)
        out[:n] = x
        return jnp.asarray(out)

    gid = np.zeros(pn, np.int32)
    if graph_ids is not None:
        gid[:n] = graph_ids
    lab = None
    if labels is not None:
        if labels.shape[0] == n:   # node labels
            lab = pad2(labels)
        else:
            lab = jnp.asarray(labels)
    return GraphBatch(
        senders=jnp.asarray(senders), receivers=jnp.asarray(receivers),
        node_mask=jnp.asarray(node_mask), edge_mask=jnp.asarray(edge_mask),
        graph_ids=jnp.asarray(gid), n_graphs=n_graphs,
        node_feat=pad2(node_feat), positions=pad2(positions),
        species=pad2(species), labels=lab)


def cora_like(n_nodes=2708, avg_deg=4.0, d_feat=1433, n_classes=7, seed=0):
    g = gen.erdos_renyi(n_nodes, avg_deg, seed=seed)
    rng = np.random.default_rng(seed)
    feat = (rng.random((g.n, d_feat)) < 0.01).astype(np.float32)
    labels = rng.integers(0, n_classes, g.n).astype(np.int32)
    return _to_batch(g, node_feat=feat, labels=labels)


def products_like(n_nodes=10000, avg_deg=8.0, d_feat=100, n_classes=47, seed=0):
    g = gen.rmat(int(np.ceil(np.log2(n_nodes))), avg_deg, seed=seed)
    rng = np.random.default_rng(seed)
    feat = rng.standard_normal((g.n, d_feat)).astype(np.float32)
    labels = rng.integers(0, n_classes, g.n).astype(np.int32)
    return _to_batch(g, node_feat=feat, labels=labels)


def molecules(n_graphs=128, n_atoms=30, seed=0, d_feat: int = 0):
    """Batch of small radius graphs with positions+species (+ optional
    one-hot-ish features for GCN/GIN)."""
    parts, pos_all, sp_all, gids = [], [], [], []
    off = 0
    rng = np.random.default_rng(seed)
    for i in range(n_graphs):
        g, pos, sp = gen.random_geometric(n_atoms, 1.6, seed=seed * 1000 + i)
        parts.append(g.edges + off)
        pos_all.append(pos); sp_all.append(sp)
        gids.append(np.full(n_atoms, i, np.int32))
        off += n_atoms
    g = UGraph(off, np.concatenate(parts))
    pos = np.concatenate(pos_all); sp = np.concatenate(sp_all)
    gid = np.concatenate(gids)
    energies = rng.standard_normal(n_graphs).astype(np.float32)
    feat = None
    if d_feat:
        feat = np.eye(max(d_feat, 8), dtype=np.float32)[sp % max(d_feat, 8)][:, :d_feat]
    return _to_batch(g, node_feat=feat, positions=pos, species=sp,
                     labels=energies, graph_ids=gid, n_graphs=n_graphs)


class NeighborSampler:
    """Layer-wise uniform fanout sampler over a CSR graph (GraphSAGE-style).

    Sampling runs in numpy (host data pipeline); the output block is a padded
    GraphBatch with exactly the static shapes of the minibatch_lg spec, so
    every training step compiles once.
    """

    def __init__(self, g: UGraph, fanout: Tuple[int, ...], seed: int = 0):
        self.indptr, self.indices, _, _ = g.csr()
        self.fanout = fanout
        self.rng = np.random.default_rng(seed)
        self.n = g.n

    def sample_block(self, seeds: np.ndarray, node_feat: np.ndarray,
                     labels: np.ndarray):
        """Returns a GraphBatch whose first len(seeds) nodes are the seeds.
        Edges point sampled-neighbor -> target (message direction)."""
        nodes = [seeds.astype(np.int64)]
        edges_s, edges_r = [], []
        frontier = seeds.astype(np.int64)
        base = 0
        for f in self.fanout:
            deg = self.indptr[frontier + 1] - self.indptr[frontier]
            # uniform sample with replacement, padded to exactly f per node
            r = self.rng.integers(0, np.maximum(deg, 1)[:, None],
                                  (len(frontier), f))
            idx = self.indptr[frontier][:, None] + r
            nbrs = self.indices[np.minimum(idx, self.indptr[frontier][:, None]
                                           + np.maximum(deg - 1, 0)[:, None])]
            nbrs = np.where(deg[:, None] > 0, nbrs, frontier[:, None])
            new = nbrs.reshape(-1)
            # local ids: targets are at [base, base+len(frontier)); new nodes
            # appended after current total
            total = sum(len(x) for x in nodes)
            src_local = total + np.arange(len(new))
            dst_local = base + np.repeat(np.arange(len(frontier)), f)
            edges_s.append(src_local); edges_r.append(dst_local)
            nodes.append(new)
            base = total
            frontier = new
        all_nodes = np.concatenate(nodes)
        s = np.concatenate(edges_s).astype(np.int32)
        r = np.concatenate(edges_r).astype(np.int32)
        N, E = len(all_nodes), len(s)
        return GraphBatch(
            senders=jnp.asarray(s), receivers=jnp.asarray(r),
            node_mask=jnp.ones(N, bool), edge_mask=jnp.ones(E, bool),
            graph_ids=jnp.zeros(N, jnp.int32), n_graphs=1,
            node_feat=jnp.asarray(node_feat[all_nodes]),
            labels=jnp.asarray(labels[all_nodes]))
