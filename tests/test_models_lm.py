"""Per-arch LM smoke tests: reduced config, one forward/train/serve step on
CPU, asserting output shapes + no NaNs (deliverable f)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import REGISTRY
from repro.models import transformer as tr
from repro.data.tokens import TokenStreamConfig, batch_at_step

LM_ARCHS = [aid for aid, e in REGISTRY.items() if e.family == "lm"]


@pytest.fixture(scope="module")
def rngkey():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_forward_and_loss(arch, rngkey):
    cfg = REGISTRY[arch].smoke_config
    params = tr.init_params(cfg, rngkey)
    tk = TokenStreamConfig(vocab=cfg.vocab, seq_len=32, global_batch=2)
    tokens, labels = batch_at_step(tk, 0)
    logits, aux = tr.forward(cfg, params, jnp.asarray(tokens))
    assert logits.shape == (2, 32, cfg.vocab)
    assert not np.isnan(np.asarray(logits, np.float32)).any()
    loss, metrics = tr.loss_fn(cfg, params, jnp.asarray(tokens),
                               jnp.asarray(labels))
    assert np.isfinite(float(loss))
    # untrained loss should be near ln(V)
    assert float(metrics["nll"]) == pytest.approx(np.log(cfg.vocab), rel=0.35)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_grad_step_no_nans(arch, rngkey):
    cfg = REGISTRY[arch].smoke_config
    params = tr.init_params(cfg, rngkey)
    tk = TokenStreamConfig(vocab=cfg.vocab, seq_len=16, global_batch=2)
    tokens, labels = batch_at_step(tk, 1)

    def f(p):
        return tr.loss_fn(cfg, p, jnp.asarray(tokens), jnp.asarray(labels))[0]

    grads = jax.grad(f)(params)
    flat, _ = jax.tree.flatten(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_prefill_decode_consistency(arch, rngkey):
    """decode(prefill(x[:-1]), x[-1]) logits == forward(x) last logits."""
    cfg = REGISTRY[arch].smoke_config
    params = tr.init_params(cfg, rngkey)
    B, S = 2, 24
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab, (B, S)).astype(np.int32)
    full_logits, _ = tr.forward(cfg, params, jnp.asarray(toks))
    last_from_full = np.asarray(full_logits[:, -1], np.float32)

    pre_logits, cache = tr.prefill(cfg, params, jnp.asarray(toks[:, :-1]))
    # grow the cache buffer to S slots for the decode step
    pad = S - cache["k"].shape[2]
    cache = {"k": jnp.pad(cache["k"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
             "v": jnp.pad(cache["v"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
             "length": cache["length"]}
    dec_logits, cache2 = tr.decode_step(cfg, params, cache,
                                        jnp.asarray(toks[:, -1]))
    got = np.asarray(dec_logits, np.float32)
    # bf16 accumulations: compare top-1 agreement + loose numeric.  MoE
    # archs get extra slack: the router matmul reduces in a different
    # order for a batched prefill vs a single-token decode step, so a
    # near-tie in bf16 can legitimately flip which expert serves the last
    # token and replace its whole FFN contribution (dense archs stay
    # within ~4e-3; both MoE archs show ~0.34 on one batch row).
    atol = 0.6 if cfg.moe_experts else 0.3
    assert np.allclose(got, last_from_full, rtol=0.15, atol=atol), (
        np.abs(got - last_from_full).max())
    assert (got.argmax(-1) == last_from_full.argmax(-1)).mean() >= 0.5
    assert int(cache2["length"][0]) == S


def test_local_global_pattern_gemma():
    cfg = REGISTRY["gemma3-12b"].config
    w = cfg.layer_windows()
    assert len(w) == 48
    assert (w[5::6] == 0).all()            # every 6th layer is global
    assert (np.delete(w, np.arange(5, 48, 6)) == 1024).all()


def test_param_counts_sane():
    assert REGISTRY["qwen2.5-32b"].config.param_count() == pytest.approx(32e9, rel=0.15)
    assert REGISTRY["qwen3-4b"].config.param_count() == pytest.approx(4e9, rel=0.25)
    mix = REGISTRY["mixtral-8x22b"].config
    assert mix.param_count() == pytest.approx(141e9, rel=0.15)
    assert mix.active_param_count() == pytest.approx(39e9, rel=0.20)
    g3 = REGISTRY["gemma3-12b"].config
    assert g3.param_count() == pytest.approx(12e9, rel=0.25)


def test_moe_dispatch_balanced_load():
    """Sorted dispatch keeps all experts busy on random tokens."""
    from repro.models.moe import MoeSpec, init_moe, moe_apply
    spec = MoeSpec(d_model=32, d_ff=64, n_experts=4, top_k=2)
    params = init_moe(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
    out, aux = moe_apply(params, x, spec)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert 0.5 < float(aux) < 4.0  # balanced ~1.0
