"""Shared transformer building blocks (pure functions, params = pytrees).

Covers the features needed by the assigned LM architectures:
  * GQA (n_kv_heads < n_heads), optional QKV bias (qwen2.5)
  * qk-norm (qwen3, gemma3)
  * RoPE
  * sliding-window attention + local:global layer patterns (gemma3, mixtral)
  * RMSNorm, SwiGLU
Attention has an impl switch: "xla" (reference einsum path — used by the
dry-run/roofline) or "pallas" (flash kernel, TPU target, validated in
interpret mode).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = jnp.float32(-1e30)


def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, D); positions: (..., S)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def make_attention_mask(q_pos, k_pos, window: Optional[jnp.ndarray] = None,
                        causal: bool = True):
    """(..., Q, K) boolean mask. window: scalar or per-layer traced value;
    <=0 or None means unbounded."""
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    mask = jnp.ones(diff.shape, bool)
    if causal:
        mask &= diff >= 0
    if window is not None:
        w = jnp.asarray(window)
        mask &= jnp.where(w > 0, diff < w, True)
    return mask


def attention_xla_chunked(q, k, v, q_pos, k_pos, window=None, causal=True,
                          chunk_q: int = 512, chunk_kv: int = 512,
                          softmax_scale: Optional[float] = None,
                          p_bf16: bool = False,
                          static_positions: bool = False,
                          static_window: Optional[int] = None):
    """Flash-style chunked attention in pure XLA: online softmax over KV
    blocks via lax.scan — O(S·chunk) memory instead of O(S²).  Numerically
    identical to ``attention_xla`` (same fp32 accumulation); property-tested
    against it.  q: (B, S, H, D); k/v: (B, K, Hkv, D).

    ``static_positions=True`` asserts q_pos/k_pos are standard aranges (q
    aligned to the end of k), enabling *static causal chunk skipping*: each
    q chunk scans only kv chunks intersecting its causal prefix — roughly
    halving attention FLOPs and HBM traffic (§Perf).  ``static_window``
    (uniform sliding window) additionally skips leading out-of-window
    chunks."""
    if static_positions and causal:
        return _attention_chunked_skipping(
            q, k, v, window, chunk_q, chunk_kv, softmax_scale, p_bf16,
            static_window)
    B, S, H, D = q.shape
    K, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / np.sqrt(D)
    cq = min(chunk_q, S)
    ck = min(chunk_kv, K)
    assert S % cq == 0 and K % ck == 0, (S, K, cq, ck)
    nq, nk = S // cq, K // ck
    qr = q.reshape(B, nq, cq, Hkv, G, D)
    kr = jnp.moveaxis(k.reshape(B, nk, ck, Hkv, D), 1, 0)   # (nk, B, ck, Hkv, D)
    vr = jnp.moveaxis(v.reshape(B, nk, ck, Hkv, D), 1, 0)
    qp = q_pos.reshape(B, nq, cq)
    kp = jnp.moveaxis(k_pos.reshape(B, nk, ck), 1, 0)        # (nk, B, ck)

    def per_q_chunk(args):
        qc, qpc = args                     # (B, cq, Hkv, G, D), (B, cq)
        qf = qc.astype(jnp.float32)

        def kv_step(carry, inp):
            m, l, acc = carry
            kc, vc, kpc = inp
            logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf,
                                kc.astype(jnp.float32)) * scale
            diff = qpc[:, None, None, :, None] - kpc[:, None, None, None, :]
            mask = jnp.ones(diff.shape, bool)
            if causal:
                mask &= diff >= 0
            if window is not None:
                w = jnp.asarray(window)
                mask &= jnp.where(w > 0, diff < w, True)
            logits = jnp.where(mask, logits, NEG_INF)
            m_new = jnp.maximum(m, logits.max(-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            if p_bf16:
                pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(jnp.bfloat16),
                                vc.astype(jnp.bfloat16)).astype(jnp.float32)
            else:
                pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, vc.astype(jnp.float32))
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, cq), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, cq, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_step), (m0, l0, a0), (kr, vr, kp))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.moveaxis(out, 3, 1).reshape(B, cq, H, D).astype(q.dtype)

    outs = jax.lax.map(per_q_chunk, (jnp.moveaxis(qr, 1, 0),
                                     jnp.moveaxis(qp, 1, 0)))
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, H, D)


def _attention_chunked_skipping(q, k, v, window, chunk_q: int, chunk_kv: int,
                                softmax_scale, p_bf16: bool,
                                static_window: Optional[int]):
    """Causal chunked attention with STATIC kv-range skipping: q chunks are
    unrolled (nq is small); each scans only kv chunks [lo, hi) where
    hi = causal bound and lo = window bound (when the window is a static
    uniform int).  Traced ``window`` still masks inside the diagonal blocks
    (gemma3's mixed local:global layers)."""
    B, S, H, D = q.shape
    K, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / np.sqrt(D)
    cq = min(chunk_q, S)
    ck = min(chunk_kv, K)
    assert S % cq == 0 and K % ck == 0, (S, K, cq, ck)
    nq, nk = S // cq, K // ck
    q_offset = K - S
    qr = q.reshape(B, nq, cq, Hkv, G, D)
    kr = jnp.moveaxis(k.reshape(B, nk, ck, Hkv, D), 1, 0)
    vr = jnp.moveaxis(v.reshape(B, nk, ck, Hkv, D), 1, 0)

    def kv_step_for(qf, q_start):
        def kv_step(carry, inp):
            m, l, acc = carry
            kc, vc, k_start = inp
            logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf,
                                kc.astype(jnp.float32)) * scale
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (cq, ck), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (cq, ck), 1)
            diff = (qpos - kpos)[None, None, None]
            mask = diff >= 0
            if window is not None:
                w = jnp.asarray(window)
                mask &= jnp.where(w > 0, diff < w, True)
            logits = jnp.where(mask, logits, NEG_INF)
            m_new = jnp.maximum(m, logits.max(-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            if p_bf16:
                pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(jnp.bfloat16),
                                vc.astype(jnp.bfloat16)).astype(jnp.float32)
            else:
                pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, vc.astype(jnp.float32))
            return (m_new, l_new, acc * corr[..., None] + pv), None
        return kv_step

    outs = []
    for qi in range(nq):
        q_start = qi * cq + q_offset
        hi = min(nk, (q_start + cq - 1) // ck + 1)          # causal bound
        lo = 0
        if static_window and static_window > 0:
            lo = max(0, (q_start - static_window + 1) // ck)
        qf = qr[:, qi].astype(jnp.float32)
        m0 = jnp.full((B, Hkv, G, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, cq), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, cq, D), jnp.float32)
        ks = jnp.asarray([i * ck for i in range(lo, hi)], jnp.int32)
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_step_for(qf, q_start)), (m0, l0, a0),
            (kr[lo:hi], vr[lo:hi], ks))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        outs.append(jnp.moveaxis(out, 3, 1).reshape(B, cq, H, D).astype(q.dtype))
    return jnp.concatenate(outs, axis=1)


def attention_xla(q, k, v, mask, softmax_scale: Optional[float] = None):
    """q: (B, Q, H, D); k/v: (B, K, Hkv, D); mask: (B|1, Q, K) or (Q, K).
    GQA: H % Hkv == 0.  Returns (B, Q, H, D)."""
    B, Q, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / np.sqrt(D)
    qf = q.astype(jnp.float32).reshape(B, Q, Hkv, G, D)
    kf = k.astype(jnp.float32)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) * scale
    while mask.ndim < 5:
        mask = mask[None]
    # mask shape -> broadcast to (B, Hkv, G, Q, K)
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Q, H, D).astype(q.dtype)


@dataclasses.dataclass(frozen=True)
class AttnParamsSpec:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool
    qk_norm: bool


def init_attn(key, spec: AttnParamsSpec, dtype=jnp.float32):
    d, H, Hkv, hd = spec.d_model, spec.n_heads, spec.n_kv_heads, spec.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(d)
    p = {
        "wq": jax.random.normal(k1, (d, H * hd), dtype) * s,
        "wk": jax.random.normal(k2, (d, Hkv * hd), dtype) * s,
        "wv": jax.random.normal(k3, (d, Hkv * hd), dtype) * s,
        "wo": jax.random.normal(k4, (H * hd, d), dtype) * (1.0 / np.sqrt(H * hd)),
    }
    if spec.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((Hkv * hd,), dtype)
        p["bv"] = jnp.zeros((Hkv * hd,), dtype)
    if spec.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def attn_qkv(params, x, spec: AttnParamsSpec, positions, rope_theta):
    """Project to rotated q, k, v. x: (B, S, d)."""
    B, S, _ = x.shape
    H, Hkv, hd = spec.n_heads, spec.n_kv_heads, spec.head_dim
    q = x @ params["wq"].astype(x.dtype)
    k = x @ params["wk"].astype(x.dtype)
    v = x @ params["wv"].astype(x.dtype)
    if spec.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, Hkv, hd)
    v = v.reshape(B, S, Hkv, hd)
    if spec.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    return q, k, v


def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / np.sqrt(d_model)
    s_out = 1.0 / np.sqrt(d_ff)
    return {
        "w_gate": jax.random.normal(k1, (d_model, d_ff), dtype) * s_in,
        "w_up": jax.random.normal(k2, (d_model, d_ff), dtype) * s_in,
        "w_down": jax.random.normal(k3, (d_ff, d_model), dtype) * s_out,
    }


def mlp_swiglu(params, x, hidden_cs=None):
    g = jax.nn.silu(x @ params["w_gate"].astype(x.dtype))
    u = x @ params["w_up"].astype(x.dtype)
    h = g * u
    if hidden_cs is not None:
        h = hidden_cs(h)
    return h @ params["w_down"].astype(x.dtype)
