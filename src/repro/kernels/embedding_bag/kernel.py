"""Pallas TPU kernel: fused EmbeddingBag (gather + segment-sum).

Grid over bag blocks; bag ids scalar-prefetched to SMEM; embedding rows DMA'd
from the HBM table and accumulated in VMEM — never materializing the
(B, L, D) gathered tensor.  Same adaptive-lookup pattern as dht_gather."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _embag_kernel(ids_ref, table_ref, o_ref, *, bb: int, L: int):
    i = pl.program_id(0)
    for b in range(bb):
        acc = jnp.zeros((1, table_ref.shape[1]), jnp.float32)
        for l in range(L):
            idx = ids_ref[i * bb + b, l]
            valid = idx > 0
            safe = jnp.maximum(idx, 0)
            row = pl.load(table_ref, (pl.ds(safe, 1), slice(None)))
            acc = acc + jnp.where(valid, row.astype(jnp.float32), 0.0)
        o_ref[b, :] = acc[0].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def embedding_bag_pallas(table, ids, block_b: int = 8, interpret: bool = True):
    """table: (V, D); ids: (B, L) -> (B, D)."""
    V, D = table.shape
    B, L = ids.shape
    bb = min(block_b, B)
    assert B % bb == 0
    kernel = functools.partial(_embag_kernel, bb=bb, L=L)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B // bb,),
            in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
            out_specs=pl.BlockSpec((bb, D), lambda i, ids: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((B, D), table.dtype),
        interpret=interpret,
    )(ids, table)
